//! The listener: a blocking connection-per-thread accept loop over
//! `std::net::TcpListener`, bounded by a connection cap.
//!
//! Topology (DESIGN.md S17): connection threads decode frames, charge
//! token buckets, and enqueue [`AdmittedFrame`]s onto the bounded
//! `net.admit` channel — they never construct queries or touch the
//! batcher (the NET-QUERY-CONFINED / NET-SINGLE-SUBMITTER lint rules).
//! The single admission front stage ([`admission::front_stage`]) is
//! the only bridge into the pipeline;
//! results come back through the responder's [`ResultTap`] into
//! per-request reply slots.
//!
//! Connection-per-thread is deliberate: a slow reader or a mid-response
//! disconnect can only stall or kill *its own* thread (write timeouts
//! bound even that), never a sibling connection — the failure-injection
//! tests drive exactly those two cases. The connection cap is the
//! outermost overload layer; its slot is released by RAII when the
//! thread exits, whatever the exit path, so a misbehaving client cannot
//! leak capacity.
//!
//! [`ResultTap`]: crate::coordinator::pipeline::ResultTap

use std::collections::BTreeMap;
use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};

use crate::coordinator::channel::{
    channel, ChannelStats, NamedSender, SendPolicy, SendResult,
};
use crate::coordinator::corpus_store::CorpusStore;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::pipeline::{Pipeline, PipelineConfig};
use crate::coordinator::server::ServeConfig;
use crate::coordinator::trace::{TraceHeader, TraceRecorder};
use crate::nn::config::{ArtifactsMeta, ModelConfig};
use crate::runtime::EngineFactory;

use super::admission::{
    front_stage, result_tap, AdmittedFrame, BucketTable, LoadSignal, ResultRouter,
};
use super::wire::{frame_len, Request, RequestFrame, Response, ResponseFrame, WireError, PREFIX_LEN};
use super::{NetConfig, NetCounters};

/// Shared state every connection thread needs. Holds the template
/// `net.admit` sender: once the accept loop and every connection thread
/// have dropped their `Arc`, the front stage's receiver disconnects and
/// the shutdown cascade proceeds.
#[derive(Debug)]
struct ConnCtx {
    shutdown: AtomicBool,
    cfg: NetConfig,
    buckets: BucketTable,
    counters: Arc<NetCounters>,
    admit_tx: NamedSender<AdmittedFrame>,
    /// Hello payload: artifact shapes + registered corpus ids.
    n_max: usize,
    num_labels: usize,
    corpora: Vec<String>,
    /// Live connection count (the cap gauge).
    active: AtomicUsize,
}

/// RAII connection slot: released when the connection thread exits,
/// whatever the exit path — the "admission token" the failure tests
/// assert is never leaked.
struct ConnSlot(Arc<ConnCtx>);

impl Drop for ConnSlot {
    fn drop(&mut self) {
        self.0.active.fetch_sub(1, Ordering::AcqRel);
    }
}

/// A running front door: listener + connection threads + admission
/// front stage + engine pipeline. `finish` for an ordered shutdown and
/// the metrics report.
#[derive(Debug)]
pub struct NetServer {
    addr: SocketAddr,
    ctx: Arc<ConnCtx>,
    accept: JoinHandle<()>,
    front: JoinHandle<()>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    admit_stats: Arc<ChannelStats>,
    counters: Arc<NetCounters>,
    signal: Arc<LoadSignal>,
    router: Arc<ResultRouter>,
    pipeline: Pipeline,
    recorder: Option<Arc<TraceRecorder>>,
}

impl NetServer {
    /// Bind `listen` (e.g. `"127.0.0.1:0"`) and start serving. Tests
    /// construct engines directly; the CLI goes through [`serve_listen`].
    pub fn start(
        model: ModelConfig,
        factories: Vec<EngineFactory>,
        pcfg: PipelineConfig,
        ncfg: NetConfig,
        corpora: Vec<Arc<CorpusStore>>,
        listen: &str,
    ) -> Result<NetServer> {
        Self::start_recorded(model, factories, pcfg, ncfg, corpora, listen, None)
    }

    /// [`NetServer::start`] with an optional workload [`TraceRecorder`]
    /// (`spa-gcn serve --listen ... --record PATH`). The recorder is
    /// handed to the admission front stage, which logs every admitted
    /// query — including degraded-GED pairs — with its arrival offset
    /// (DESIGN.md S19).
    pub fn start_recorded(
        model: ModelConfig,
        factories: Vec<EngineFactory>,
        pcfg: PipelineConfig,
        ncfg: NetConfig,
        corpora: Vec<Arc<CorpusStore>>,
        listen: &str,
        recorder: Option<Arc<TraceRecorder>>,
    ) -> Result<NetServer> {
        let router = Arc::new(ResultRouter::new());
        let counters = Arc::new(NetCounters::default());
        let signal = Arc::new(LoadSignal::new(ncfg.degrade_hi, ncfg.degrade_lo));
        let pipeline = Pipeline::start_with_tap(
            model.clone(),
            factories,
            pcfg,
            Some(result_tap(&router)),
        );

        let (admit_tx, admit_rx) =
            channel("net.admit", ncfg.admit_cap.max(1), SendPolicy::DropNewest);
        let admit_stats = admit_tx.stats();

        let corpora: BTreeMap<String, Arc<CorpusStore>> = corpora
            .into_iter()
            .map(|c| (c.name().to_string(), c))
            .collect();

        let ctx = Arc::new(ConnCtx {
            shutdown: AtomicBool::new(false),
            buckets: BucketTable::new(&ncfg),
            counters: Arc::clone(&counters),
            admit_tx,
            n_max: model.n_max,
            num_labels: model.num_labels,
            corpora: corpora.keys().cloned().collect(),
            active: AtomicUsize::new(0),
            cfg: ncfg.clone(),
        });

        let front = {
            let submit_handle = pipeline.submit_handle();
            let router = Arc::clone(&router);
            let signal = Arc::clone(&signal);
            let counters = Arc::clone(&counters);
            let recorder = recorder.clone();
            std::thread::Builder::new()
                .name("spa-net-front".into())
                .spawn(move || {
                    front_stage(
                        admit_rx,
                        submit_handle,
                        router,
                        corpora,
                        signal,
                        counters,
                        model,
                        ncfg,
                        recorder,
                    )
                })
                .context("spawning net front stage")?
        };

        let listener = TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
        let addr = listener.local_addr().context("reading bound address")?;
        // Non-blocking accept + poll: shutdown needs no self-connect
        // nudge, at the cost of a few ms accept latency.
        listener
            .set_nonblocking(true)
            .context("setting listener non-blocking")?;

        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let ctx = Arc::clone(&ctx);
            let conns = Arc::clone(&conns);
            std::thread::Builder::new()
                .name("spa-net-accept".into())
                .spawn(move || accept_loop(listener, ctx, conns))
                .context("spawning net accept loop")?
        };

        Ok(NetServer {
            addr,
            ctx,
            accept,
            front,
            conns,
            admit_stats,
            counters,
            signal,
            router,
            pipeline,
            recorder,
        })
    }

    /// The bound address (resolves `:0` test binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until every engine lane's caps handshake has published;
    /// returns working-lane count (see [`Pipeline::wait_ready`]).
    pub fn wait_ready(&self) -> usize {
        let lanes = self.pipeline.wait_ready();
        // Rebase the trace epoch to "lanes ready": recorded arrival
        // offsets then measure the serving window, not engine warmup.
        if let Some(rec) = &self.recorder {
            rec.rebase();
        }
        lanes
    }

    /// Live front-door counters (tests assert on these mid-run).
    pub fn counters(&self) -> Arc<NetCounters> {
        Arc::clone(&self.counters)
    }

    /// The degraded-mode load signal (observability).
    pub fn load_signal(&self) -> Arc<LoadSignal> {
        Arc::clone(&self.signal)
    }

    /// Outstanding result routes (leak detection in tests).
    pub fn pending_routes(&self) -> usize {
        self.router.pending()
    }

    /// Live connection count (cap-slot leak detection in tests).
    pub fn active_connections(&self) -> usize {
        self.ctx.active.load(Ordering::Acquire)
    }

    /// Connection JoinHandles still tracked by the accept loop
    /// (handle-leak detection in tests: finished threads are reaped on
    /// accept-loop ticks, so this tracks live connections, not total
    /// connections ever served).
    pub fn tracked_conn_handles(&self) -> usize {
        self.conns.lock().unwrap_or_else(|p| p.into_inner()).len()
    }

    /// Ordered shutdown: stop accepting, drain connections, let the
    /// front stage finish the admission queue, then collect pipeline
    /// metrics with the net counters and `net.admit` snapshot attached.
    pub fn finish(self) -> Metrics {
        // Destructuring consumes every handle exactly once — `finish`
        // takes `self` by value, so "runs once" is a type-level fact.
        let NetServer {
            ctx,
            accept,
            front,
            conns,
            admit_stats,
            counters,
            pipeline,
            recorder,
            ..
        } = self;
        ctx.shutdown.store(true, Ordering::Release);
        let _ = accept.join();
        // Connection threads notice the flag within read_timeout_ms (or
        // finish their in-flight request first) and drop their ConnCtx
        // Arcs; with the accept loop's Arc gone too, the front stage's
        // receiver disconnects after the queue drains.
        let handles: Vec<_> = {
            let mut conns = conns.lock().unwrap_or_else(|p| p.into_inner());
            conns.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
        drop(ctx);
        let _ = front.join();
        // Only now is the front stage's SubmitHandle dropped, so
        // Pipeline::finish's drop cascade can start.
        let mut metrics = pipeline.finish();
        metrics.net = Some(counters.snapshot());
        metrics.channels.push(admit_stats.snapshot());
        // The front thread's recorder clone is gone by now; flush the
        // trace. A PANIC-FREE scope can only warn on failure here —
        // the CLI path surfaces it to stderr, tests read the file.
        if let Some(rec) = recorder {
            if !rec.finish() {
                eprintln!("net: trace recording failed (unwritable --record path?)");
            }
        }
        metrics
    }
}

/// CLI entrypoint (`spa-gcn serve --listen ADDR`): build engines from
/// the artifacts directory per `cfg`, synthesize the corpus when
/// `--corpus N` asked for one, and start the front door. The net knobs
/// arrive separately from the pipeline config: `ServeConfig` is a
/// coordinator type and must not depend on this layer (ARCH-DAG).
pub fn serve_listen(cfg: &ServeConfig, ncfg: NetConfig, listen: &str) -> Result<NetServer> {
    anyhow::ensure!(!cfg.engines.is_empty(), "serve needs at least one engine kind");
    let meta = ArtifactsMeta::load(&cfg.artifacts_dir)
        .context("loading artifacts (run `make artifacts`)")?;
    let model = meta.config.clone();
    let mut corpora = Vec::new();
    if cfg.corpus_size > 0 {
        // Same family/seed recipe as the in-process `serve` workload, so
        // a given seed means the same corpus either way.
        let mut rng = crate::util::rng::Rng::new(cfg.seed);
        let db = crate::graph::dataset::GraphDb::synthesize(
            &mut rng,
            crate::graph::generate::Family::Aids,
            cfg.corpus_size,
            model.n_max,
            model.num_labels,
        );
        corpora.push(Arc::new(
            CorpusStore::from_db("aids-synth", &db, model.n_max, model.num_labels)
                .map_err(|e| anyhow::anyhow!("encoding corpus: {e}"))?,
        ));
    }
    let recorder = match &cfg.record {
        Some(path) => Some(Arc::new(
            TraceRecorder::create(
                path,
                &TraceHeader {
                    seed: cfg.seed,
                    corpus_size: cfg.corpus_size,
                    topk: cfg.topk,
                    n_max: model.n_max,
                    num_labels: model.num_labels,
                },
            )
            .map_err(|e| anyhow::anyhow!("opening --record {}: {e}", path.display()))?,
        )),
        None => None,
    };
    NetServer::start_recorded(
        model,
        cfg.lane_factories(),
        cfg.pipeline_config(),
        ncfg,
        corpora,
        listen,
        recorder,
    )
}

// ---------------------------------------------------------------------
// Accept loop + connection threads
// ---------------------------------------------------------------------

/// Join and drop every connection handle whose thread has exited.
/// Called from the accept loop's idle ticks and before tracking a new
/// connection: on a long-running server the handle list stays
/// proportional to *live* connections (<= conn cap), not to total
/// connections ever served — finished threads release their OS
/// resources promptly instead of at shutdown.
fn reap_finished(conns: &Mutex<Vec<JoinHandle<()>>>) {
    let mut conns = conns.lock().unwrap_or_else(|p| p.into_inner());
    let mut i = 0;
    while i < conns.len() {
        if conns[i].is_finished() {
            // Finished thread: join returns immediately.
            let _ = conns.swap_remove(i).join();
        } else {
            i += 1;
        }
    }
}

fn accept_loop(listener: TcpListener, ctx: Arc<ConnCtx>, conns: Arc<Mutex<Vec<JoinHandle<()>>>>) {
    let mut conn_id = 0u64;
    while !ctx.shutdown.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                reap_finished(&conns);
                // Connection cap: acquire a slot or answer busy. CAS
                // loop so two racing accepts can't both take the last
                // slot (single accept thread today, but cheap to keep
                // correct).
                let acquired = ctx
                    .active
                    .fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| {
                        (n < ctx.cfg.conn_cap).then_some(n + 1)
                    })
                    .is_ok();
                if !acquired {
                    ctx.counters.note_throttled();
                    let mut stream = stream;
                    // BSD-derived platforms inherit the listener's
                    // O_NONBLOCK on accept; clear it so the busy answer
                    // is a plain bounded write.
                    let _ = stream.set_nonblocking(false);
                    let _ = stream.set_write_timeout(Some(Duration::from_millis(
                        ctx.cfg.write_timeout_ms.max(100),
                    )));
                    let _ = write_response(
                        &mut stream,
                        &ResponseFrame {
                            id: 0,
                            resp: Response::Error {
                                code: "busy".into(),
                                detail: format!(
                                    "connection cap {} reached; retry",
                                    ctx.cfg.conn_cap
                                ),
                            },
                        },
                    );
                    continue;
                }
                let slot = ConnSlot(Arc::clone(&ctx));
                let ctx = Arc::clone(&ctx);
                conn_id += 1;
                let handle = std::thread::Builder::new()
                    .name(format!("spa-net-conn.{conn_id}"))
                    .spawn(move || run_conn(stream, ctx, slot));
                match handle {
                    Ok(h) => conns.lock().unwrap_or_else(|p| p.into_inner()).push(h),
                    Err(e) => eprintln!("net: spawning connection thread failed: {e}"),
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                reap_finished(&conns);
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => {
                eprintln!("net: accept error: {e}");
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

/// One connection's request/response loop. The `_slot` guard releases
/// the connection-cap slot on every exit path.
fn run_conn(mut stream: TcpStream, ctx: Arc<ConnCtx>, _slot: ConnSlot) {
    // The listener is non-blocking and BSD-derived platforms (macOS
    // included) make accepted sockets inherit O_NONBLOCK; clear it
    // first or every read returns WouldBlock immediately, turning
    // read_full_idle into a busy-spin and the read timeout below into
    // a no-op. (Linux does not inherit the flag, so tests there would
    // never catch the spin.)
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(ctx.cfg.read_timeout_ms.max(10))));
    let _ = stream.set_write_timeout(Some(Duration::from_millis(ctx.cfg.write_timeout_ms.max(100))));
    loop {
        let body = match read_frame_idle(&mut stream, ctx.cfg.max_frame, &ctx) {
            Ok(Some(body)) => body,
            // Clean EOF on a frame boundary, or server shutdown.
            Ok(None) => return,
            Err(err) => {
                // Framing desync (oversized/truncated frame, io): answer
                // typed best-effort, then the connection must close.
                let _ = write_response(
                    &mut stream,
                    &ResponseFrame {
                        id: 0,
                        resp: Response::Error {
                            code: err.code().into(),
                            detail: err.to_string(),
                        },
                    },
                );
                return;
            }
        };
        let frame = match RequestFrame::decode(&body) {
            Ok(frame) => frame,
            Err(err) => {
                // Body-level error on an intact frame boundary: the
                // connection survives.
                let ok = write_response(
                    &mut stream,
                    &ResponseFrame {
                        id: 0,
                        resp: Response::Error {
                            code: err.code().into(),
                            detail: err.to_string(),
                        },
                    },
                );
                if ok.is_err() {
                    return;
                }
                continue;
            }
        };
        let resp = match frame.req {
            Request::Hello => ResponseFrame {
                id: frame.id,
                resp: Response::Hello {
                    n_max: ctx.n_max,
                    num_labels: ctx.num_labels,
                    corpora: ctx.corpora.clone(),
                },
            },
            req => match admit_and_wait(&ctx, frame.client, frame.id, req) {
                Some(resp) => resp,
                None => ResponseFrame {
                    id: frame.id,
                    resp: Response::Error {
                        code: "timeout".into(),
                        detail: "response did not arrive in time".into(),
                    },
                },
            },
        };
        if write_response(&mut stream, &resp).is_err() {
            return;
        }
    }
}

/// Token bucket → admission queue → wait on the per-request reply slot.
/// Every overload path returns a typed response; `None` only for the
/// (pathological) case of a reply that never arrived.
fn admit_and_wait(
    ctx: &ConnCtx,
    client: String,
    request_id: u64,
    req: Request,
) -> Option<ResponseFrame> {
    if let Err(retry) = ctx.buckets.admit(&client) {
        ctx.counters.note_throttled();
        return Some(ResponseFrame {
            id: request_id,
            resp: Response::Throttled {
                retry_after_ms: (retry.as_millis() as u64).max(1),
            },
        });
    }
    let (reply_tx, reply_rx) = sync_channel(1);
    let admitted = AdmittedFrame {
        client,
        request_id,
        req,
        deadline: Instant::now() + Duration::from_millis(ctx.cfg.deadline_ms),
        reply: reply_tx,
    };
    match ctx.admit_tx.send(admitted) {
        SendResult::Sent => {
            ctx.counters.note_accepted();
            // Generous grace past the shed deadline: the reply is
            // normally either the score or the shed/throttle answer,
            // and the pipeline outlives every connection thread — this
            // bound exists for pathological stalls only.
            let grace = Duration::from_millis(ctx.cfg.deadline_ms.saturating_mul(4) + 30_000);
            reply_rx.recv_timeout(grace).ok()
        }
        SendResult::Dropped => {
            // DropNewest shed the frame at the queue door: same answer
            // as an empty token bucket — come back shortly.
            ctx.counters.note_throttled();
            Some(ResponseFrame {
                id: request_id,
                resp: Response::Throttled {
                    retry_after_ms: ctx.cfg.deadline_ms.max(1),
                },
            })
        }
        SendResult::Full(_) | SendResult::Disconnected(_) => Some(ResponseFrame {
            id: request_id,
            resp: Response::Error {
                code: "shutting_down".into(),
                detail: "front door is shutting down".into(),
            },
        }),
    }
}

fn write_response(stream: &mut TcpStream, frame: &ResponseFrame) -> Result<(), WireError> {
    super::wire::write_frame(stream, &frame.encode())
}

enum FullRead {
    Complete,
    /// Peer closed before the first byte of this read.
    CleanEof,
    /// Peer closed (or stalled past the deadline) mid-buffer.
    Partial(usize),
    /// Server shutdown flag observed.
    Shutdown,
    /// Deadline passed with no byte received: the peer is idle, not
    /// truncating.
    IdleTimeout,
}

/// Shutdown-aware frame read: socket read timeouts double as poll
/// points for the shutdown flag, and partial reads accumulate across
/// them (a timeout mid-frame loses nothing). Each read (prefix, then
/// body) gets `idle_timeout_ms` to make progress: an idle peer between
/// frames is closed quietly and its conn-cap slot released — 64 silent
/// TCP connections must not pin the cap forever — and a peer that
/// stalls mid-frame (slow-loris) is bounded by the same deadline,
/// surfacing as a truncation error.
fn read_frame_idle(
    stream: &mut TcpStream,
    max: usize,
    ctx: &ConnCtx,
) -> Result<Option<Vec<u8>>, WireError> {
    let idle = Duration::from_millis(ctx.cfg.idle_timeout_ms.max(100));
    let mut prefix = [0u8; PREFIX_LEN];
    match read_full_idle(stream, &mut prefix, ctx, Instant::now() + idle)? {
        FullRead::Complete => {}
        // Idle past the deadline on a frame boundary: close like a
        // clean EOF, freeing the connection slot.
        FullRead::CleanEof | FullRead::Shutdown | FullRead::IdleTimeout => return Ok(None),
        FullRead::Partial(got) => {
            return Err(WireError::Truncated {
                wanted: PREFIX_LEN,
                got,
            })
        }
    }
    let len = frame_len(&prefix, max)?;
    let mut body = vec![0u8; len];
    match read_full_idle(stream, &mut body, ctx, Instant::now() + idle)? {
        FullRead::Complete => Ok(Some(body)),
        FullRead::Shutdown => Ok(None),
        // A prefix with no body inside the deadline is a stall
        // mid-frame, not idleness: fatal, typed.
        FullRead::CleanEof | FullRead::IdleTimeout => {
            Err(WireError::Truncated { wanted: len, got: 0 })
        }
        FullRead::Partial(got) => Err(WireError::Truncated { wanted: len, got }),
    }
}

fn read_full_idle(
    stream: &mut TcpStream,
    buf: &mut [u8],
    ctx: &ConnCtx,
    deadline: Instant,
) -> Result<FullRead, WireError> {
    let mut got = 0;
    loop {
        if got == buf.len() {
            return Ok(FullRead::Complete);
        }
        if ctx.shutdown.load(Ordering::Acquire) {
            return Ok(FullRead::Shutdown);
        }
        if Instant::now() >= deadline {
            return Ok(if got == 0 {
                FullRead::IdleTimeout
            } else {
                FullRead::Partial(got)
            });
        }
        match stream.read(&mut buf[got..]) {
            Ok(0) => {
                return Ok(if got == 0 {
                    FullRead::CleanEof
                } else {
                    FullRead::Partial(got)
                })
            }
            Ok(n) => got += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(e) => return Err(WireError::Io(e.to_string())),
        }
    }
}
