//! Wire codec: length-prefixed frames carrying a versioned JSON body.
//!
//! Layout: a 4-byte big-endian body length, then the body — a JSON
//! object (hand-rolled `util::json`; the offline build vendors no
//! serde). Every body carries `"v"`: decoding rejects unknown versions
//! with a typed error instead of guessing, so the protocol can evolve.
//!
//! The decoder is hostile-input-safe by construction: the length
//! prefix is validated against the configured maximum *before* any
//! allocation (no length-prefix-driven OOM), truncated bodies and
//! malformed JSON come back as typed [`WireError`]s, and graph payloads
//! are validated (label arity, endpoint range) before touching
//! [`Graph::new`], whose invariants are asserts. Nothing in this module
//! panics on untrusted bytes — the codec unit tests fuzz that.
//!
//! Scores cross the wire bit-identical: an `f32` widened to `f64` is
//! exact, the JSON writer prints the shortest round-trip `f64` repr,
//! and narrowing back to `f32` is exact again — so a score read off the
//! socket equals the in-process [`QueryResult::score`] bit for bit
//! (the e2e test asserts this).
//!
//! [`QueryResult::score`]: crate::coordinator::query::QueryResult::score

use std::io::{Read, Write};

use crate::graph::Graph;
use crate::util::json::{self, Json};

/// Protocol version stamped into (and required from) every body.
pub const WIRE_VERSION: u64 = 1;

/// Frame length prefix size, bytes.
pub const PREFIX_LEN: usize = 4;

/// Typed codec failures. Framing errors (`FrameTooLarge`, `Truncated`)
/// desynchronize the stream and are fatal per-connection; body errors
/// (`BadJson`, `UnknownVersion`, `Malformed`) arrive on intact frame
/// boundaries and are answered with a typed error response instead.
#[derive(Debug, Clone, PartialEq)]
pub enum WireError {
    /// Length prefix exceeds the configured maximum; rejected before
    /// allocating.
    FrameTooLarge { len: usize, max: usize },
    /// The stream ended inside a frame (prefix or body).
    Truncated { wanted: usize, got: usize },
    /// Socket-level failure.
    Io(String),
    /// Body is not valid JSON.
    BadJson(String),
    /// Body's `"v"` is not [`WIRE_VERSION`].
    UnknownVersion(u64),
    /// Body parsed but a field is missing or out of range.
    Malformed(String),
}

impl WireError {
    /// Short machine-readable code for error responses.
    pub fn code(&self) -> &'static str {
        match self {
            WireError::FrameTooLarge { .. } => "frame_too_large",
            WireError::Truncated { .. } => "truncated",
            WireError::Io(_) => "io",
            WireError::BadJson(_) => "bad_json",
            WireError::UnknownVersion(_) => "unknown_version",
            WireError::Malformed(_) => "malformed",
        }
    }

    /// Whether the stream is desynchronized (connection must close).
    pub fn is_fatal(&self) -> bool {
        matches!(
            self,
            WireError::FrameTooLarge { .. } | WireError::Truncated { .. } | WireError::Io(_)
        )
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::FrameTooLarge { len, max } => {
                write!(f, "frame body {len} B exceeds max {max} B")
            }
            WireError::Truncated { wanted, got } => {
                write!(f, "stream ended mid-frame ({got}/{wanted} B)")
            }
            WireError::Io(e) => write!(f, "io: {e}"),
            WireError::BadJson(e) => write!(f, "bad json: {e}"),
            WireError::UnknownVersion(v) => {
                write!(f, "unknown wire version {v} (this end speaks {WIRE_VERSION})")
            }
            WireError::Malformed(e) => write!(f, "malformed request: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

/// Write one frame: length prefix + body.
pub fn write_frame<W: Write>(w: &mut W, body: &[u8]) -> Result<(), WireError> {
    let len = u32::try_from(body.len())
        .map_err(|_| WireError::Malformed(format!("frame body {} B overflows u32", body.len())))?;
    w.write_all(&len.to_be_bytes())
        .and_then(|()| w.write_all(body))
        .and_then(|()| w.flush())
        .map_err(|e| WireError::Io(e.to_string()))
}

/// Read one frame body, bounding allocation by `max`. `Ok(None)` is a
/// clean EOF on a frame boundary (peer closed between requests).
/// Blocking — the server's shutdown-aware poll loop lives in
/// `net::server`; this is the client-side read.
pub fn read_frame<R: Read>(r: &mut R, max: usize) -> Result<Option<Vec<u8>>, WireError> {
    let mut prefix = [0u8; PREFIX_LEN];
    match read_full(r, &mut prefix)? {
        0 => return Ok(None),
        n if n < PREFIX_LEN => {
            return Err(WireError::Truncated {
                wanted: PREFIX_LEN,
                got: n,
            })
        }
        _ => {}
    }
    let len = frame_len(&prefix, max)?;
    let mut body = vec![0u8; len];
    let got = read_full(r, &mut body)?;
    if got < len {
        return Err(WireError::Truncated { wanted: len, got });
    }
    Ok(Some(body))
}

/// Validate a length prefix against the frame cap — the one place the
/// no-alloc-before-check rule is enforced.
pub fn frame_len(prefix: &[u8; PREFIX_LEN], max: usize) -> Result<usize, WireError> {
    let len = u32::from_be_bytes(*prefix) as usize;
    if len > max {
        return Err(WireError::FrameTooLarge { len, max });
    }
    Ok(len)
}

/// `read_exact` that reports how many bytes landed instead of losing
/// them on EOF (so truncation errors can say how far they got).
fn read_full<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<usize, WireError> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => break,
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e.to_string())),
        }
    }
    Ok(got)
}

// ---------------------------------------------------------------------
// Request bodies
// ---------------------------------------------------------------------

/// What a client asks of the front door.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Shape/corpus discovery: what n_max / label arity to generate
    /// graphs with, and which corpus ids are rankable.
    Hello,
    /// Score one graph pair.
    Pair { g1: Graph, g2: Graph },
    /// Rank a registered corpus (by id) against a query graph.
    /// `budget` 0 = exact ranking; > 0 prunes the candidate set to at
    /// most that many with cheap signals before the model tail runs.
    TopK {
        corpus: String,
        graph: Graph,
        k: usize,
        budget: usize,
    },
    /// Insert or replace one candidate in a live corpus. Publishes a
    /// new epoch snapshot unless the graph is fingerprint-identical to
    /// the current entry at that id (dedup no-op).
    Upsert { corpus: String, id: u64, graph: Graph },
    /// Remove one candidate from a live corpus (unknown ids are no-ops).
    Remove { corpus: String, id: u64 },
}

/// A decoded request frame: routing header + payload.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestFrame {
    /// Token-bucket identity from the frame header. Empty = the shared
    /// anonymous bucket.
    pub client: String,
    /// Client-chosen correlation id, echoed on the response.
    pub id: u64,
    pub req: Request,
}

impl RequestFrame {
    pub fn encode(&self) -> Vec<u8> {
        let mut fields = vec![
            ("v", json::num(WIRE_VERSION as f64)),
            ("client", json::s(&self.client)),
            ("id", json::num(self.id as f64)),
        ];
        match &self.req {
            Request::Hello => fields.push(("kind", json::s("hello"))),
            Request::Pair { g1, g2 } => {
                fields.push(("kind", json::s("pair")));
                fields.push(("g1", graph_to_json(g1)));
                fields.push(("g2", graph_to_json(g2)));
            }
            Request::TopK {
                corpus,
                graph,
                k,
                budget,
            } => {
                fields.push(("kind", json::s("topk")));
                fields.push(("corpus", json::s(corpus)));
                fields.push(("graph", graph_to_json(graph)));
                fields.push(("k", json::num(*k as f64)));
                // Encoded only when set: exact-mode frames stay
                // byte-identical to the pre-cascade protocol.
                if *budget > 0 {
                    fields.push(("budget", json::num(*budget as f64)));
                }
            }
            Request::Upsert { corpus, id, graph } => {
                fields.push(("kind", json::s("upsert")));
                fields.push(("corpus", json::s(corpus)));
                fields.push(("cid", json::num(*id as f64)));
                fields.push(("graph", graph_to_json(graph)));
            }
            Request::Remove { corpus, id } => {
                fields.push(("kind", json::s("remove")));
                fields.push(("corpus", json::s(corpus)));
                fields.push(("cid", json::num(*id as f64)));
            }
        }
        json::obj(fields).to_string().into_bytes()
    }

    pub fn decode(body: &[u8]) -> Result<Self, WireError> {
        let v = parse_versioned(body)?;
        let client = v.get("client").as_str().unwrap_or("").to_string();
        let id = field_u64(&v, "id")?;
        let req = match v.get("kind").as_str() {
            Some("hello") => Request::Hello,
            Some("pair") => Request::Pair {
                g1: graph_from_json(v.get("g1"), "g1")?,
                g2: graph_from_json(v.get("g2"), "g2")?,
            },
            Some("topk") => {
                let corpus = v
                    .get("corpus")
                    .as_str()
                    .ok_or_else(|| WireError::Malformed("topk needs a corpus id".into()))?
                    .to_string();
                let k = field_u64(&v, "k")? as usize;
                if k == 0 {
                    return Err(WireError::Malformed("k must be >= 1".into()));
                }
                // Absent on pre-cascade frames: default to exact.
                let budget = match v.get("budget") {
                    Json::Null => 0,
                    _ => field_u64(&v, "budget")? as usize,
                };
                Request::TopK {
                    corpus,
                    graph: graph_from_json(v.get("graph"), "graph")?,
                    k,
                    budget,
                }
            }
            Some("upsert") => Request::Upsert {
                corpus: v
                    .get("corpus")
                    .as_str()
                    .ok_or_else(|| WireError::Malformed("upsert needs a corpus id".into()))?
                    .to_string(),
                id: field_u64(&v, "cid")?,
                graph: graph_from_json(v.get("graph"), "graph")?,
            },
            Some("remove") => Request::Remove {
                corpus: v
                    .get("corpus")
                    .as_str()
                    .ok_or_else(|| WireError::Malformed("remove needs a corpus id".into()))?
                    .to_string(),
                id: field_u64(&v, "cid")?,
            },
            Some(other) => {
                return Err(WireError::Malformed(format!("unknown request kind '{other}'")))
            }
            None => return Err(WireError::Malformed("missing request kind".into())),
        };
        Ok(RequestFrame { client, id, req })
    }
}

// ---------------------------------------------------------------------
// Response bodies
// ---------------------------------------------------------------------

/// What the front door answers. Every overload outcome is a first-class
/// response, not a dropped connection.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Hello {
        n_max: usize,
        num_labels: usize,
        corpora: Vec<String>,
    },
    Score {
        score: f32,
        /// Served by the degraded lane (GED heuristic, not the engine).
        degraded: bool,
    },
    TopK {
        ranked: Vec<(u64, f32)>,
        /// k was shrunk by the degraded mode.
        degraded: bool,
        /// Corpus epoch the ranking was computed against (0 from
        /// pre-epoch servers).
        epoch: u64,
    },
    /// A corpus mutation (upsert/remove) committed: the store's epoch
    /// after the mutation and its candidate count. A dedup or
    /// unknown-id no-op answers with the unchanged epoch.
    Mutated { epoch: u64, size: usize },
    /// Token bucket empty or admission queue full: come back in
    /// `retry_after_ms`, nothing was queued.
    Throttled { retry_after_ms: u64 },
    /// Typed failure (codec, unknown corpus, deadline shed, engine...).
    Error { code: String, detail: String },
}

/// A response frame: the request's correlation id + payload.
#[derive(Debug, Clone, PartialEq)]
pub struct ResponseFrame {
    pub id: u64,
    pub resp: Response,
}

impl ResponseFrame {
    pub fn encode(&self) -> Vec<u8> {
        let mut fields = vec![
            ("v", json::num(WIRE_VERSION as f64)),
            ("id", json::num(self.id as f64)),
        ];
        match &self.resp {
            Response::Hello {
                n_max,
                num_labels,
                corpora,
            } => {
                fields.push(("kind", json::s("hello")));
                fields.push(("n_max", json::num(*n_max as f64)));
                fields.push(("num_labels", json::num(*num_labels as f64)));
                fields.push((
                    "corpora",
                    json::arr(corpora.iter().map(|c| json::s(c)).collect()),
                ));
            }
            Response::Score { score, degraded } => {
                fields.push(("kind", json::s("score")));
                fields.push(("score", json::num(*score as f64)));
                fields.push(("degraded", Json::Bool(*degraded)));
            }
            Response::TopK {
                ranked,
                degraded,
                epoch,
            } => {
                fields.push(("kind", json::s("topk")));
                fields.push((
                    "ranked",
                    json::arr(
                        ranked
                            .iter()
                            .map(|(id, s)| {
                                json::arr(vec![json::num(*id as f64), json::num(*s as f64)])
                            })
                            .collect(),
                    ),
                ));
                fields.push(("degraded", Json::Bool(*degraded)));
                fields.push(("epoch", json::num(*epoch as f64)));
            }
            Response::Mutated { epoch, size } => {
                fields.push(("kind", json::s("mutated")));
                fields.push(("epoch", json::num(*epoch as f64)));
                fields.push(("size", json::num(*size as f64)));
            }
            Response::Throttled { retry_after_ms } => {
                fields.push(("kind", json::s("throttled")));
                fields.push(("retry_after_ms", json::num(*retry_after_ms as f64)));
            }
            Response::Error { code, detail } => {
                fields.push(("kind", json::s("error")));
                fields.push(("code", json::s(code)));
                fields.push(("detail", json::s(detail)));
            }
        }
        json::obj(fields).to_string().into_bytes()
    }

    pub fn decode(body: &[u8]) -> Result<Self, WireError> {
        let v = parse_versioned(body)?;
        let id = field_u64(&v, "id")?;
        let resp = match v.get("kind").as_str() {
            Some("hello") => Response::Hello {
                n_max: field_u64(&v, "n_max")? as usize,
                num_labels: field_u64(&v, "num_labels")? as usize,
                corpora: v
                    .get("corpora")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|c| c.as_str().map(str::to_string))
                    .collect(),
            },
            Some("score") => Response::Score {
                score: field_f64(&v, "score")? as f32,
                degraded: v.get("degraded").as_bool().unwrap_or(false),
            },
            Some("topk") => {
                let ranked = v
                    .get("ranked")
                    .as_arr()
                    .ok_or_else(|| WireError::Malformed("topk response needs ranked".into()))?
                    .iter()
                    .map(|pair| {
                        let pair = pair
                            .as_arr()
                            .filter(|p| p.len() == 2)
                            .ok_or_else(|| WireError::Malformed("ranked entry not a pair".into()))?;
                        let id = pair[0]
                            .as_f64()
                            .filter(|x| *x >= 0.0 && x.fract() == 0.0)
                            .ok_or_else(|| WireError::Malformed("ranked id not a u64".into()))?;
                        let score = pair[1]
                            .as_f64()
                            .ok_or_else(|| WireError::Malformed("ranked score not a number".into()))?;
                        Ok((id as u64, score as f32))
                    })
                    .collect::<Result<Vec<_>, WireError>>()?;
                Response::TopK {
                    ranked,
                    degraded: v.get("degraded").as_bool().unwrap_or(false),
                    epoch: match v.get("epoch") {
                        Json::Null => 0,
                        _ => field_u64(&v, "epoch")?,
                    },
                }
            }
            Some("mutated") => Response::Mutated {
                epoch: field_u64(&v, "epoch")?,
                size: field_u64(&v, "size")? as usize,
            },
            Some("throttled") => Response::Throttled {
                retry_after_ms: field_u64(&v, "retry_after_ms")?,
            },
            Some("error") => Response::Error {
                code: v.get("code").as_str().unwrap_or("unknown").to_string(),
                detail: v.get("detail").as_str().unwrap_or("").to_string(),
            },
            Some(other) => {
                return Err(WireError::Malformed(format!("unknown response kind '{other}'")))
            }
            None => return Err(WireError::Malformed("missing response kind".into())),
        };
        Ok(ResponseFrame { id, resp })
    }
}

// ---------------------------------------------------------------------
// Shared body helpers
// ---------------------------------------------------------------------

fn parse_versioned(body: &[u8]) -> Result<Json, WireError> {
    let text = std::str::from_utf8(body).map_err(|e| WireError::BadJson(e.to_string()))?;
    let v = json::parse(text).map_err(WireError::BadJson)?;
    match v.get("v").as_f64() {
        Some(ver) if ver == WIRE_VERSION as f64 => Ok(v),
        Some(ver) if ver >= 0.0 && ver.fract() == 0.0 && ver < u64::MAX as f64 => {
            Err(WireError::UnknownVersion(ver as u64))
        }
        _ => Err(WireError::UnknownVersion(0)),
    }
}

fn field_f64(v: &Json, key: &str) -> Result<f64, WireError> {
    v.get(key)
        .as_f64()
        .ok_or_else(|| WireError::Malformed(format!("missing numeric field '{key}'")))
}

fn field_u64(v: &Json, key: &str) -> Result<u64, WireError> {
    // 2^53 bound: ids ride JSON f64s, exact only below that. Client ids
    // are correlation counters in practice; reject rather than alias.
    field_f64(v, key)
        .ok()
        .filter(|x| *x >= 0.0 && x.fract() == 0.0 && *x <= 9_007_199_254_740_992.0)
        .map(|x| x as u64)
        .ok_or_else(|| WireError::Malformed(format!("field '{key}' is not a non-negative integer")))
}

/// Graph payload: `{"n":5,"labels":[...],"edges":[[u,v],...]}`.
pub fn graph_to_json(g: &Graph) -> Json {
    json::obj(vec![
        ("n", json::num(g.num_nodes() as f64)),
        (
            "labels",
            json::arr(g.labels().iter().map(|&l| json::num(l as f64)).collect()),
        ),
        (
            "edges",
            json::arr(
                g.edges()
                    .iter()
                    .map(|&(u, v)| json::arr(vec![json::num(u as f64), json::num(v as f64)]))
                    .collect(),
            ),
        ),
    ])
}

/// Node-count sanity bound on wire graphs. SPA-GCN targets small graphs
/// (n_max 32 in the shipped artifacts); the net front stage separately
/// validates every decoded graph against the *model's* n_max /
/// num_labels (`router::validate_graph` in `net/admission.rs`) before
/// any scoring lane runs. This coarser wire bound exists only so a
/// hostile frame can't make the decoder build a huge graph first.
pub const MAX_WIRE_NODES: usize = 4096;

/// Decode and *validate* a graph payload: label arity, u16 ranges and
/// endpoint bounds are checked here because [`Graph::new`]'s invariants
/// are asserts — untrusted input must never reach them.
pub fn graph_from_json(v: &Json, what: &str) -> Result<Graph, WireError> {
    let bad = |detail: String| WireError::Malformed(format!("{what}: {detail}"));
    if v.as_obj().is_none() {
        return Err(bad("not an object".into()));
    }
    let n = v
        .get("n")
        .as_f64()
        .filter(|x| *x >= 0.0 && x.fract() == 0.0)
        .map(|x| x as usize)
        .ok_or_else(|| bad("missing node count 'n'".into()))?;
    if n > MAX_WIRE_NODES {
        return Err(bad(format!("n={n} exceeds wire bound {MAX_WIRE_NODES}")));
    }
    let labels_json = v
        .get("labels")
        .as_arr()
        .ok_or_else(|| bad("missing 'labels' array".into()))?;
    if labels_json.len() != n {
        return Err(bad(format!("{} labels for {n} nodes", labels_json.len())));
    }
    let labels = labels_json
        .iter()
        .map(|l| {
            l.as_f64()
                .filter(|x| *x >= 0.0 && x.fract() == 0.0 && *x <= u16::MAX as f64)
                .map(|x| x as u16)
        })
        .collect::<Option<Vec<u16>>>()
        .ok_or_else(|| bad("label out of u16 range".into()))?;
    let edges_json = v
        .get("edges")
        .as_arr()
        .ok_or_else(|| bad("missing 'edges' array".into()))?;
    let mut edges = Vec::with_capacity(edges_json.len());
    for e in edges_json {
        let pair = e
            .as_arr()
            .filter(|p| p.len() == 2)
            .ok_or_else(|| bad("edge is not a [u,v] pair".into()))?;
        let endpoint = |x: &Json| {
            x.as_f64()
                .filter(|x| *x >= 0.0 && x.fract() == 0.0 && (*x as usize) < n)
                .map(|x| x as u16)
        };
        match (endpoint(&pair[0]), endpoint(&pair[1])) {
            (Some(u), Some(w)) => edges.push((u, w)),
            _ => return Err(bad(format!("edge endpoint out of range for n={n}"))),
        }
    }
    Ok(Graph::new(n, edges, labels))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{generate, Family};
    use crate::util::rng::Rng;

    fn frame_bytes(body: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        write_frame(&mut out, body).unwrap();
        out
    }

    #[test]
    fn frame_roundtrip() {
        let body = br#"{"v":1,"kind":"hello","client":"","id":0}"#.to_vec();
        let bytes = frame_bytes(&body);
        let mut r = &bytes[..];
        assert_eq!(read_frame(&mut r, 1024).unwrap(), Some(body));
        // Clean EOF on the boundary.
        assert_eq!(read_frame(&mut r, 1024).unwrap(), None);
    }

    #[test]
    fn request_roundtrip_property() {
        // Property over random graphs: encode → decode is identity for
        // every request kind, across sizes and label arities.
        let mut rng = Rng::new(0x77);
        for trial in 0..50u64 {
            let g1 = generate(&mut rng, Family::Aids, 32, 29);
            let g2 = generate(&mut rng, Family::ErdosRenyi { n: 9, p_millis: 350 }, 32, 8);
            let req = match trial % 5 {
                0 => Request::Hello,
                1 => Request::Pair {
                    g1: g1.clone(),
                    g2: g2.clone(),
                },
                2 => Request::Upsert {
                    corpus: format!("corpus-{trial}"),
                    id: trial * 31,
                    graph: g2.clone(),
                },
                3 => Request::Remove {
                    corpus: format!("corpus-{trial}"),
                    id: trial * 7,
                },
                _ => Request::TopK {
                    corpus: format!("corpus-{trial}"),
                    graph: g1.clone(),
                    k: 1 + (trial as usize % 17),
                    // Exercise both exact (0) and budgeted frames.
                    budget: (trial as usize % 3) * 100,
                },
            };
            let frame = RequestFrame {
                client: format!("client-{}", trial % 5),
                id: trial * 1_000_003,
                req,
            };
            let decoded = RequestFrame::decode(&frame.encode()).unwrap();
            assert_eq!(decoded, frame);
        }
    }

    #[test]
    fn response_roundtrip_property() {
        let cases = vec![
            Response::Hello {
                n_max: 32,
                num_labels: 29,
                corpora: vec!["aids-synth".into(), "x".into()],
            },
            Response::Score {
                score: 0.734_218_2_f32,
                degraded: false,
            },
            Response::Score {
                score: 1.0,
                degraded: true,
            },
            Response::TopK {
                ranked: vec![(3, 0.9f32), (0, 0.12345678f32), (u32::MAX as u64, 0.0)],
                degraded: true,
                epoch: 0,
            },
            Response::TopK {
                ranked: vec![(8, 0.5f32)],
                degraded: false,
                epoch: 41,
            },
            Response::Mutated { epoch: 7, size: 4097 },
            Response::Throttled { retry_after_ms: 17 },
            Response::Error {
                code: "deadline".into(),
                detail: "waited 300ms".into(),
            },
        ];
        for (i, resp) in cases.into_iter().enumerate() {
            let frame = ResponseFrame {
                id: i as u64,
                resp,
            };
            assert_eq!(ResponseFrame::decode(&frame.encode()).unwrap(), frame);
        }
    }

    #[test]
    fn budget_field_is_backward_compatible() {
        // A pre-cascade frame (no budget key) decodes as exact mode...
        let legacy = br#"{"v":1,"client":"","id":3,"kind":"topk","corpus":"c","k":2,"graph":{"n":1,"labels":[0],"edges":[]}}"#;
        match RequestFrame::decode(legacy).unwrap().req {
            Request::TopK { budget, .. } => assert_eq!(budget, 0),
            other => panic!("wrong kind: {other:?}"),
        }
        // ...and an exact-mode frame encodes without the budget key, so
        // old servers still parse it.
        let frame = RequestFrame {
            client: String::new(),
            id: 3,
            req: Request::TopK {
                corpus: "c".into(),
                graph: Graph::new(1, vec![], vec![0]),
                k: 2,
                budget: 0,
            },
        };
        let body = String::from_utf8(frame.encode()).unwrap();
        assert!(!body.contains("budget"), "{body}");
        // A mistyped budget is rejected, not defaulted.
        let bad = br#"{"v":1,"client":"","id":3,"kind":"topk","corpus":"c","k":2,"budget":-5,"graph":{"n":1,"labels":[0],"edges":[]}}"#;
        assert!(matches!(RequestFrame::decode(bad), Err(WireError::Malformed(_))));
        // Same story for the response's epoch: absent defaults to 0.
        let legacy_resp = br#"{"v":1,"id":1,"kind":"topk","ranked":[[2,0.5]],"degraded":false}"#;
        match ResponseFrame::decode(legacy_resp).unwrap().resp {
            Response::TopK { epoch, .. } => assert_eq!(epoch, 0),
            other => panic!("wrong kind: {other:?}"),
        }
    }

    #[test]
    fn scores_roundtrip_bit_identical() {
        // The f32 → f64 → shortest-repr → f64 → f32 chain is exact for
        // every f32, including awkward ones.
        let mut rng = Rng::new(9);
        let mut scores: Vec<f32> = (0..200).map(|_| rng.f32()).collect();
        scores.extend([0.0, 1.0, f32::MIN_POSITIVE, 0.1, 1.0 / 3.0]);
        for s in scores {
            let frame = ResponseFrame {
                id: 1,
                resp: Response::Score {
                    score: s,
                    degraded: false,
                },
            };
            match ResponseFrame::decode(&frame.encode()).unwrap().resp {
                Response::Score { score, .. } => {
                    assert_eq!(score.to_bits(), s.to_bits(), "score {s} corrupted in transit")
                }
                other => panic!("wrong kind: {other:?}"),
            }
        }
    }

    #[test]
    fn oversized_prefix_rejected_before_alloc() {
        // A hostile 4 GiB length prefix must come back as a typed error
        // without the decoder allocating the claimed body.
        let mut bytes = u32::MAX.to_be_bytes().to_vec();
        bytes.extend_from_slice(b"xx");
        match read_frame(&mut &bytes[..], 1 << 20) {
            Err(WireError::FrameTooLarge { len, max }) => {
                assert_eq!(len, u32::MAX as usize);
                assert_eq!(max, 1 << 20);
            }
            other => panic!("expected FrameTooLarge, got {other:?}"),
        }
        assert!(WireError::FrameTooLarge { len: 0, max: 0 }.is_fatal());
    }

    #[test]
    fn truncated_prefix_and_body_are_typed() {
        // Stream dies inside the prefix.
        let bytes = [0u8, 0];
        match read_frame(&mut &bytes[..], 1024) {
            Err(WireError::Truncated { wanted, got }) => {
                assert_eq!((wanted, got), (PREFIX_LEN, 2));
            }
            other => panic!("expected Truncated, got {other:?}"),
        }
        // Stream dies inside the body.
        let mut bytes = 10u32.to_be_bytes().to_vec();
        bytes.extend_from_slice(b"abc");
        match read_frame(&mut &bytes[..], 1024) {
            Err(WireError::Truncated { wanted, got }) => assert_eq!((wanted, got), (10, 3)),
            other => panic!("expected Truncated, got {other:?}"),
        }
    }

    #[test]
    fn unknown_version_is_typed() {
        let body = br#"{"v":2,"kind":"hello","id":0}"#;
        match RequestFrame::decode(body) {
            Err(WireError::UnknownVersion(2)) => {}
            other => panic!("expected UnknownVersion(2), got {other:?}"),
        }
        // Missing version is its own rejection, not a default.
        assert!(matches!(
            RequestFrame::decode(br#"{"kind":"hello","id":0}"#),
            Err(WireError::UnknownVersion(_))
        ));
    }

    #[test]
    fn malformed_bodies_are_typed_never_panics() {
        let cases: Vec<&[u8]> = vec![
            b"",
            b"not json at all",
            b"\xff\xfe\x00",
            br#"{"v":1}"#,
            br#"{"v":1,"kind":"nope","id":0}"#,
            br#"{"v":1,"kind":"pair","id":0}"#,
            br#"{"v":1,"kind":"pair","id":0,"g1":5,"g2":6}"#,
            // labels arity mismatch
            br#"{"v":1,"kind":"pair","id":0,"g1":{"n":3,"labels":[1],"edges":[]},"g2":{"n":1,"labels":[0],"edges":[]}}"#,
            // edge endpoint out of range — must NOT reach Graph::new's assert
            br#"{"v":1,"kind":"pair","id":0,"g1":{"n":2,"labels":[0,1],"edges":[[0,9]]},"g2":{"n":1,"labels":[0],"edges":[]}}"#,
            // negative / fractional fields
            br#"{"v":1,"kind":"topk","id":-4,"corpus":"c","k":3,"graph":{"n":1,"labels":[0],"edges":[]}}"#,
            br#"{"v":1,"kind":"topk","id":0,"corpus":"c","k":0,"graph":{"n":1,"labels":[0],"edges":[]}}"#,
            br#"{"v":1,"kind":"topk","id":0,"corpus":"c","k":2.5,"graph":{"n":1,"labels":[0],"edges":[]}}"#,
            // hostile node count: bounded before any label/edge work
            br#"{"v":1,"kind":"pair","id":0,"g1":{"n":99999999,"labels":[],"edges":[]},"g2":{"n":1,"labels":[0],"edges":[]}}"#,
        ];
        for body in cases {
            let err = RequestFrame::decode(body)
                .expect_err(&format!("accepted {:?}", String::from_utf8_lossy(body)));
            // Body-level errors arrive on intact frame boundaries: the
            // connection survives and answers with a typed error.
            assert!(
                matches!(err, WireError::BadJson(_) | WireError::Malformed(_)),
                "unexpected error class {err:?} for {:?}",
                String::from_utf8_lossy(body)
            );
        }
    }

    #[test]
    fn random_bytes_never_panic_decoder() {
        // Fuzz the full read path: arbitrary byte soup must yield typed
        // errors (or valid frames), never a panic or huge allocation.
        let mut rng = Rng::new(0xF00D);
        for _ in 0..300 {
            let len = rng.below(64);
            let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            if let Ok(Some(body)) = read_frame(&mut &bytes[..], 4096) {
                let _ = RequestFrame::decode(&body);
                let _ = ResponseFrame::decode(&body);
            }
        }
    }

    #[test]
    fn graph_codec_roundtrip() {
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let g = generate(&mut rng, Family::Aids, 32, 29);
            let back = graph_from_json(&graph_to_json(&g), "g").unwrap();
            assert_eq!(back, g);
        }
    }
}
