//! Loopback client: drive the front door over a real socket.
//!
//! [`NetClient`] is a minimal blocking caller (one outstanding request
//! per connection — responses come back in order). [`run_load`] is the
//! `spa-gcn load --connect` workload: N client threads, each with its
//! own connection, client id, and Poisson schedule (reusing
//! `coordinator::load` pacing), classifying every typed response the
//! overload taxonomy can produce. It exists so overload behavior —
//! throttling, shedding, degraded scoring — is drivable end-to-end in
//! tests and benches without external tools.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};

use crate::coordinator::load::{poisson_schedule, Pacer};
use crate::graph::generate::{generate, Family};
use crate::report::{fmt, Table};
use crate::util::rng::Rng;

use super::wire::{
    read_frame, write_frame, Request, RequestFrame, Response, ResponseFrame, WireError,
};

/// A blocking wire-protocol client over one connection.
#[derive(Debug)]
pub struct NetClient {
    stream: TcpStream,
    client_id: String,
    next_id: u64,
    max_frame: usize,
}

impl NetClient {
    /// Connect to a front door. `client_id` names the token bucket this
    /// connection's requests are charged to.
    pub fn connect(addr: &str, client_id: &str) -> Result<NetClient, WireError> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| WireError::Io(format!("connect {addr}: {e}")))?;
        let _ = stream.set_nodelay(true);
        // A response that takes this long means the server is gone;
        // surface it as a typed Io error instead of hanging the client.
        let _ = stream.set_read_timeout(Some(Duration::from_secs(60)));
        Ok(NetClient {
            stream,
            client_id: client_id.to_string(),
            next_id: 1,
            max_frame: 1 << 20,
        })
    }

    /// Send one request, block for its response frame.
    pub fn call(&mut self, req: Request) -> Result<ResponseFrame, WireError> {
        let id = self.next_id;
        self.next_id += 1;
        let frame = RequestFrame {
            client: self.client_id.clone(),
            id,
            req,
        };
        write_frame(&mut self.stream, &frame.encode())?;
        match read_frame(&mut self.stream, self.max_frame)? {
            Some(body) => ResponseFrame::decode(&body),
            None => Err(WireError::Io("connection closed before response".into())),
        }
    }

    /// Shape/corpus discovery: `(n_max, num_labels, corpus ids)`.
    pub fn hello(&mut self) -> Result<(usize, usize, Vec<String>), WireError> {
        match self.call(Request::Hello)?.resp {
            Response::Hello {
                n_max,
                num_labels,
                corpora,
            } => Ok((n_max, num_labels, corpora)),
            other => Err(WireError::Malformed(format!(
                "unexpected hello reply: {other:?}"
            ))),
        }
    }

    /// Score one pair.
    pub fn pair(&mut self, g1: crate::graph::Graph, g2: crate::graph::Graph) -> Result<ResponseFrame, WireError> {
        self.call(Request::Pair { g1, g2 })
    }

    /// Rank `corpus` against `graph` (exact mode).
    pub fn topk(
        &mut self,
        corpus: &str,
        graph: crate::graph::Graph,
        k: usize,
    ) -> Result<ResponseFrame, WireError> {
        self.topk_budgeted(corpus, graph, k, 0)
    }

    /// Rank `corpus` against `graph`; `budget > 0` asks the server for
    /// the coarse-to-fine cascade with that candidate budget.
    pub fn topk_budgeted(
        &mut self,
        corpus: &str,
        graph: crate::graph::Graph,
        k: usize,
        budget: usize,
    ) -> Result<ResponseFrame, WireError> {
        self.call(Request::TopK {
            corpus: corpus.into(),
            graph,
            k,
            budget,
        })
    }

    /// Insert or replace candidate `id` in `corpus`.
    pub fn upsert(
        &mut self,
        corpus: &str,
        id: u64,
        graph: crate::graph::Graph,
    ) -> Result<ResponseFrame, WireError> {
        self.call(Request::Upsert {
            corpus: corpus.into(),
            id,
            graph,
        })
    }

    /// Remove candidate `id` from `corpus`.
    pub fn remove(&mut self, corpus: &str, id: u64) -> Result<ResponseFrame, WireError> {
        self.call(Request::Remove {
            corpus: corpus.into(),
            id,
        })
    }
}

/// `spa-gcn load --connect` configuration.
#[derive(Debug, Clone)]
pub struct LoadConfig {
    /// Front-door address, e.g. `127.0.0.1:7700`.
    pub connect: String,
    /// Client threads; each gets its own connection, id (`load.N`), and
    /// token bucket.
    pub clients: usize,
    /// Total offered rate across all clients (Poisson arrivals).
    pub rate_qps: f64,
    /// Total queries across all clients.
    pub queries: usize,
    /// Workload RNG seed.
    pub seed: u64,
    /// 0 = pair queries; > 0 = top-k against the server's first
    /// advertised corpus at this depth.
    pub topk: usize,
    /// 0 = exact top-k; > 0 = budgeted cascade with this candidate
    /// budget (only meaningful with `topk > 0`).
    pub budget: usize,
    /// Corpus upserts to interleave into the workload (total across all
    /// clients); exercises epoch swaps under live queries.
    pub upserts: usize,
}

impl Default for LoadConfig {
    fn default() -> Self {
        LoadConfig {
            connect: "127.0.0.1:7700".into(),
            clients: 4,
            rate_qps: 200.0,
            queries: 1000,
            seed: 42,
            topk: 0,
            budget: 0,
            upserts: 0,
        }
    }
}

/// Per-thread outcome tally; merged for the report. Every variant of
/// the typed response taxonomy has a row — an unclassifiable answer is
/// a bug, not an "other".
#[derive(Debug, Default, Clone)]
pub struct LoadStats {
    pub sent: u64,
    pub ok: u64,
    pub degraded: u64,
    pub throttled: u64,
    pub shed: u64,
    pub errors: u64,
    pub io_errors: u64,
    /// Acknowledged corpus mutations (upsert/remove).
    pub mutated: u64,
    /// Response latencies for scored answers only, ms.
    pub latencies_ms: Vec<f64>,
    pub max_late: Duration,
}

impl LoadStats {
    fn merge(&mut self, other: LoadStats) {
        self.sent += other.sent;
        self.ok += other.ok;
        self.degraded += other.degraded;
        self.throttled += other.throttled;
        self.shed += other.shed;
        self.errors += other.errors;
        self.io_errors += other.io_errors;
        self.mutated += other.mutated;
        self.latencies_ms.extend(other.latencies_ms);
        self.max_late = self.max_late.max(other.max_late);
    }

    /// Classify one response frame into the tally.
    pub fn note(&mut self, resp: &Response) {
        match resp {
            Response::Score { degraded, .. } | Response::TopK { degraded, .. } => {
                self.ok += 1;
                if *degraded {
                    self.degraded += 1;
                }
            }
            Response::Mutated { .. } => self.mutated += 1,
            Response::Throttled { .. } => self.throttled += 1,
            Response::Error { code, .. } if code == "deadline" => self.shed += 1,
            Response::Error { .. } | Response::Hello { .. } => self.errors += 1,
        }
    }
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// One client thread's loop: paced sends over its own connection. A
/// wire-level error ends the thread (the stream is desynced); typed
/// overload answers do not.
fn load_client(
    cfg: &LoadConfig,
    idx: usize,
    n_max: usize,
    num_labels: usize,
    corpus: Option<String>,
    count: usize,
    upserts: usize,
) -> LoadStats {
    let mut stats = LoadStats::default();
    let mut client = match NetClient::connect(&cfg.connect, &format!("load.{idx}")) {
        Ok(c) => c,
        Err(_) => {
            stats.io_errors += 1;
            return stats;
        }
    };
    // Distinct stream per client; the workload itself (not the pacing
    // draws) is what must be reproducible, so a simple seed offset is
    // enough.
    let mut rng = Rng::new(cfg.seed.wrapping_add(1 + idx as u64));
    let per_client_rate = (cfg.rate_qps / cfg.clients.max(1) as f64).max(1e-6);
    // Synthesize up front: generation jitter must not pollute pacing.
    let graphs: Vec<_> = (0..count * 2)
        .map(|_| generate(&mut rng, Family::Aids, n_max, num_labels))
        .collect();
    let upsert_graphs: Vec<_> = (0..upserts)
        .map(|_| generate(&mut rng, Family::Aids, n_max, num_labels))
        .collect();
    let schedule = poisson_schedule(&mut rng, per_client_rate, count);
    let pacer = Pacer::new();
    // Spread this client's upsert share across its schedule, so epoch
    // swaps land while queries are in flight rather than in one burst.
    let upsert_every = if upserts > 0 { (count / upserts).max(1) } else { 0 };
    let mut sent_upserts = 0usize;
    for (i, at) in schedule.into_iter().enumerate() {
        stats.max_late = stats.max_late.max(pacer.wait_until(at));
        if let Some(name) = &corpus {
            if upsert_every > 0 && i % upsert_every == 0 && sent_upserts < upserts {
                // Ids far above the synthesized corpus range (0..N), and
                // disjoint per client, so clients never fight over one id.
                let id = 1_000_000 + (idx as u64) * 100_000 + sent_upserts as u64;
                let g = upsert_graphs[sent_upserts].clone();
                match client.upsert(name, id, g) {
                    Ok(frame) => {
                        stats.sent += 1;
                        stats.note(&frame.resp);
                    }
                    Err(_) => {
                        stats.io_errors += 1;
                        return stats;
                    }
                }
                sent_upserts += 1;
            }
        }
        let sent_at = Instant::now();
        let result = match (&corpus, cfg.topk) {
            (Some(name), k) if k > 0 => {
                client.topk_budgeted(name, graphs[i * 2].clone(), k, cfg.budget)
            }
            _ => client.pair(graphs[i * 2].clone(), graphs[i * 2 + 1].clone()),
        };
        stats.sent += 1;
        match result {
            Ok(frame) => {
                let scored = matches!(
                    frame.resp,
                    Response::Score { .. } | Response::TopK { .. }
                );
                stats.note(&frame.resp);
                if scored {
                    stats.latencies_ms.push(sent_at.elapsed().as_secs_f64() * 1e3);
                }
            }
            Err(_) => {
                stats.io_errors += 1;
                return stats;
            }
        }
    }
    stats
}

/// Drive a front door with a paced open-loop workload and report the
/// typed-outcome tally (CLI `spa-gcn load --connect`).
pub fn run_load(cfg: &LoadConfig) -> Result<Table> {
    anyhow::ensure!(cfg.rate_qps > 0.0, "load needs --rate > 0");
    anyhow::ensure!(cfg.clients > 0, "load needs at least one client");
    // Shape discovery on a probe connection, so generated graphs match
    // the server's artifacts.
    let mut probe = NetClient::connect(&cfg.connect, "load.probe")
        .map_err(|e| anyhow::anyhow!("connecting probe to {}: {e}", cfg.connect))?;
    let (n_max, num_labels, corpora) = probe
        .hello()
        .map_err(|e| anyhow::anyhow!("hello handshake: {e}"))?;
    drop(probe);
    let corpus = corpora.first().cloned();
    anyhow::ensure!(
        cfg.topk == 0 || corpus.is_some(),
        "server advertises no corpus; top-k load needs `serve --corpus N`"
    );

    anyhow::ensure!(
        cfg.upserts == 0 || corpus.is_some(),
        "server advertises no corpus; --upserts needs `serve --corpus N`"
    );

    let base = cfg.queries / cfg.clients;
    let extra = cfg.queries % cfg.clients;
    let ubase = cfg.upserts / cfg.clients;
    let uextra = cfg.upserts % cfg.clients;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for idx in 0..cfg.clients {
        let count = base + usize::from(idx < extra);
        if count == 0 {
            continue;
        }
        let upserts = ubase + usize::from(idx < uextra);
        let cfg = cfg.clone();
        let corpus = corpus.clone();
        handles.push(std::thread::spawn(move || {
            load_client(&cfg, idx, n_max, num_labels, corpus, count, upserts)
        }));
    }
    let mut stats = LoadStats::default();
    for h in handles {
        match h.join() {
            Ok(s) => stats.merge(s),
            Err(_) => stats.io_errors += 1,
        }
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);

    let mut lat = stats.latencies_ms.clone();
    lat.sort_by(|a, b| a.total_cmp(b));
    let mut t = Table::new(
        &format!(
            "load: connect={} clients={} rate={:.0} q/s queries={}{}",
            cfg.connect,
            cfg.clients,
            cfg.rate_qps,
            cfg.queries,
            match (cfg.topk, cfg.budget, cfg.upserts) {
                (0, _, u) if u == 0 => String::new(),
                (k, b, u) => format!(" topk={k} budget={b} upserts={u}"),
            }
        ),
        &["metric", "value"],
    );
    t.row(vec!["sent".into(), stats.sent.to_string()]);
    t.row(vec!["scored ok".into(), stats.ok.to_string()]);
    t.row(vec!["degraded responses".into(), stats.degraded.to_string()]);
    t.row(vec!["throttled".into(), stats.throttled.to_string()]);
    t.row(vec!["shed (deadline)".into(), stats.shed.to_string()]);
    t.row(vec!["errors".into(), stats.errors.to_string()]);
    t.row(vec!["io errors".into(), stats.io_errors.to_string()]);
    t.row(vec!["mutations acked".into(), stats.mutated.to_string()]);
    t.row(vec!["latency p50 (ms)".into(), fmt(percentile(&lat, 0.50))]);
    t.row(vec!["latency p95 (ms)".into(), fmt(percentile(&lat, 0.95))]);
    t.row(vec![
        "achieved rate (q/s)".into(),
        fmt(stats.sent as f64 / wall),
    ]);
    t.row(vec![
        "max pacing lateness (ms)".into(),
        fmt(stats.max_late.as_secs_f64() * 1e3),
    ]);
    t.row(vec!["wall time (s)".into(), fmt(wall)]);
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_note_classifies_every_variant() {
        let mut s = LoadStats::default();
        s.note(&Response::Score {
            score: 0.5,
            degraded: false,
        });
        s.note(&Response::TopK {
            ranked: vec![],
            degraded: true,
            epoch: 3,
        });
        s.note(&Response::Mutated { epoch: 4, size: 65 });
        s.note(&Response::Throttled { retry_after_ms: 5 });
        s.note(&Response::Error {
            code: "deadline".into(),
            detail: String::new(),
        });
        s.note(&Response::Error {
            code: "engine".into(),
            detail: String::new(),
        });
        assert_eq!(
            (s.ok, s.degraded, s.mutated, s.throttled, s.shed, s.errors),
            (2, 1, 1, 1, 1, 1)
        );
    }

    #[test]
    fn percentile_handles_edges() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[3.0], 0.95), 3.0);
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        let p50 = percentile(&v, 0.5);
        assert!((49.0..=51.0).contains(&p50), "{p50}");
    }

    #[test]
    fn load_stats_merge_accumulates() {
        let mut a = LoadStats {
            sent: 3,
            ok: 2,
            latencies_ms: vec![1.0],
            max_late: Duration::from_millis(2),
            ..LoadStats::default()
        };
        let b = LoadStats {
            sent: 2,
            throttled: 1,
            latencies_ms: vec![4.0],
            max_late: Duration::from_millis(7),
            ..LoadStats::default()
        };
        a.merge(b);
        assert_eq!((a.sent, a.ok, a.throttled), (5, 2, 1));
        assert_eq!(a.latencies_ms, vec![1.0, 4.0]);
        assert_eq!(a.max_late, Duration::from_millis(7));
    }
}
