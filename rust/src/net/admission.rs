//! Admission front stage: token buckets, deadline shedding at dequeue,
//! the queue-depth EWMA load signal, and the degraded scoring mode.
//!
//! Connection threads never talk to the batcher. Every scoring request
//! crosses one bounded `NamedChannel` (`net.admit`, policy
//! [`SendPolicy::DropNewest`]) into the single front-stage thread, which
//! is the only code in `net/` that constructs [`Query`]s and calls
//! [`SubmitHandle::submit`] — the NET-QUERY-CONFINED and
//! NET-SINGLE-SUBMITTER lint rules pin that topology. The front
//! stage is where the overload taxonomy's inner layers live:
//!
//! * **Throttle** (connection thread, before the queue): a per-client
//!   token bucket answers `retry_after_ms` instead of queueing. The
//!   admission queue dropping the newest arrival is the same answer —
//!   backpressure is pushed to the client, never accumulated.
//! * **Shed** (front stage, at dequeue): a frame that already waited
//!   past its deadline is answered with a typed error, not scored —
//!   scoring it would spend engine time on a response the client has
//!   stopped waiting for. Sheds are counted on the channel
//!   ([`ChannelStats::note_shed`]) and in `net shed (deadline)`.
//! * **Reject** (front stage, before lane selection): every wire graph
//!   is validated against the model's `n_max` / `num_labels` with the
//!   same `router::validate_graph` the in-process admission stage
//!   uses, so no lane — engine *or* the degraded GED fallback — ever
//!   sees a shape the artifacts can't serve.
//! * **Degrade** (front stage, under the EWMA load signal): top-k
//!   queries shrink to `degraded_topk`, and pair queries fall back to
//!   the `ged::heuristics` bound-based scorer — the coarse half of a
//!   LW-GCN-style cheap-lane cascade. Degradation is recorded on the
//!   response (`degraded: true`) and in `degraded responses`.
//!
//! [`SendPolicy::DropNewest`]: crate::coordinator::channel::SendPolicy::DropNewest
//! [`ChannelStats::note_shed`]: crate::coordinator::channel::ChannelStats::note_shed

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::SyncSender;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::channel::NamedReceiver;
use crate::coordinator::corpus_store::CorpusStore;
use crate::coordinator::pipeline::{ResultTap, SubmitHandle};
use crate::coordinator::query::{CascadeMode, Outcome, Query, QueryResult};
use crate::coordinator::router::validate_graph;
use crate::coordinator::trace::TraceRecorder;
use crate::ged::ged_similarity;
use crate::ged::heuristics::greedy_ged;
use crate::nn::config::ModelConfig;

use super::wire::{Request, Response, ResponseFrame};
use super::{NetConfig, NetCounters};

/// One client's token bucket: `burst` capacity, `rate` tokens/s refill,
/// lazily advanced on each take.
#[derive(Debug)]
pub struct TokenBucket {
    tokens: f64,
    last: Instant,
    rate: f64,
    burst: f64,
}

impl TokenBucket {
    pub fn new(rate: f64, burst: f64, now: Instant) -> Self {
        TokenBucket {
            tokens: burst.max(1.0),
            last: now,
            rate: rate.max(0.0),
            burst: burst.max(1.0),
        }
    }

    /// Take one token, or report how long until one refills.
    pub fn try_take(&mut self, now: Instant) -> Result<(), Duration> {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            Ok(())
        } else {
            let secs = if self.rate > 0.0 {
                (1.0 - self.tokens) / self.rate
            } else {
                f64::INFINITY
            };
            // Clamp: retry-after is advice, not a promise; a zero-rate
            // bucket still answers something finite.
            Err(Duration::from_secs_f64(secs.clamp(0.001, 60.0)))
        }
    }
}

/// Per-client buckets, keyed by the frame header's client id. Bounded:
/// past `max_clients` distinct ids, new clients share the anonymous
/// (`""`) bucket, so hostile id churn can't grow the table without
/// limit.
#[derive(Debug)]
pub struct BucketTable {
    rate: f64,
    burst: f64,
    max_clients: usize,
    buckets: Mutex<HashMap<String, TokenBucket>>,
}

impl BucketTable {
    pub fn new(cfg: &NetConfig) -> Self {
        BucketTable {
            rate: cfg.refill_per_s,
            burst: cfg.burst,
            max_clients: cfg.max_clients.max(1),
            buckets: Mutex::new(HashMap::new()),
        }
    }

    /// Charge one request to `client`'s bucket.
    pub fn admit(&self, client: &str) -> Result<(), Duration> {
        let now = Instant::now();
        let mut map = self.buckets.lock().unwrap_or_else(|p| p.into_inner());
        let key = if map.contains_key(client) || map.len() < self.max_clients {
            client
        } else {
            ""
        };
        map.entry(key.to_string())
            .or_insert_with(|| TokenBucket::new(self.rate, self.burst, now))
            .try_take(now)
    }

    /// Distinct buckets currently tracked (tests).
    pub fn tracked(&self) -> usize {
        self.buckets.lock().unwrap_or_else(|p| p.into_inner()).len()
    }
}

/// Queue-depth EWMA with hysteresis: degraded mode engages at `hi`,
/// disengages below `lo`. Written by the front-stage thread only; the
/// atomics exist so connection threads and reports can read it.
#[derive(Debug)]
pub struct LoadSignal {
    ewma_bits: AtomicU64,
    degraded: AtomicBool,
    hi: f64,
    lo: f64,
    alpha: f64,
}

impl LoadSignal {
    pub fn new(hi: f64, lo: f64) -> Self {
        LoadSignal {
            ewma_bits: AtomicU64::new(0f64.to_bits()),
            degraded: AtomicBool::new(false),
            hi,
            lo: lo.min(hi),
            alpha: 0.2,
        }
    }

    /// Fold one queue-depth observation (as a fraction of capacity)
    /// into the EWMA; returns whether the degraded mode is now engaged.
    pub fn observe(&self, fraction: f64) -> bool {
        let prev = f64::from_bits(self.ewma_bits.load(Ordering::Relaxed));
        let next = prev + self.alpha * (fraction - prev);
        self.ewma_bits.store(next.to_bits(), Ordering::Relaxed);
        let engaged = if self.degraded.load(Ordering::Relaxed) {
            next > self.lo
        } else {
            next >= self.hi
        };
        self.degraded.store(engaged, Ordering::Relaxed);
        engaged
    }

    pub fn ewma(&self) -> f64 {
        f64::from_bits(self.ewma_bits.load(Ordering::Relaxed))
    }

    pub fn is_degraded(&self) -> bool {
        self.degraded.load(Ordering::Relaxed)
    }
}

/// A frame that passed its token bucket, en route to the front stage.
#[derive(Debug)]
pub struct AdmittedFrame {
    /// Client id (telemetry only past this point).
    pub client: String,
    /// Client-chosen correlation id, echoed on the response.
    pub request_id: u64,
    /// Pair or TopK (Hello is answered at the connection layer).
    pub req: Request,
    /// Shed-at-dequeue bound: arrival time + the configured deadline.
    pub deadline: Instant,
    /// Per-request reply slot. Capacity 1 and written at most once, so
    /// sends never block the front stage or the responder tap; a
    /// disconnected client just makes the send a no-op.
    pub reply: SyncSender<ResponseFrame>,
}

#[derive(Debug)]
struct PendingReply {
    request_id: u64,
    degraded: bool,
    /// Corpus epoch the query was admitted against (0 for pair
    /// queries), echoed on the top-k response.
    epoch: u64,
    reply: SyncSender<ResponseFrame>,
}

/// Routes pipeline results back to the connection threads waiting on
/// them. The front stage assigns each submitted query a process-unique
/// internal id (client ids from different connections may collide);
/// the responder's [`ResultTap`] looks the internal id back up and
/// forwards a [`ResponseFrame`] carrying the client's own id.
#[derive(Debug)]
pub struct ResultRouter {
    next: AtomicU64,
    routes: Mutex<HashMap<u64, PendingReply>>,
}

impl Default for ResultRouter {
    fn default() -> Self {
        Self::new()
    }
}

impl ResultRouter {
    pub fn new() -> Self {
        ResultRouter {
            next: AtomicU64::new(1),
            routes: Mutex::new(HashMap::new()),
        }
    }

    /// Claim an internal query id and register where its result goes.
    /// `epoch` is the corpus snapshot the query was admitted against
    /// (0 for pair queries); it is echoed on the top-k response.
    pub fn register(
        &self,
        request_id: u64,
        degraded: bool,
        epoch: u64,
        reply: SyncSender<ResponseFrame>,
    ) -> u64 {
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        self.routes
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .insert(
                id,
                PendingReply {
                    request_id,
                    degraded,
                    epoch,
                    reply,
                },
            );
        id
    }

    /// Drop a registration whose submit failed.
    pub fn cancel(&self, internal_id: u64) {
        self.routes
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .remove(&internal_id);
    }

    /// Forward one pipeline result to its waiting connection; false if
    /// the result was not a net-routed query (in-process submits share
    /// the pipeline).
    pub fn deliver(&self, r: &QueryResult) -> bool {
        let Some(pending) = self
            .routes
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .remove(&r.id)
        else {
            return false;
        };
        let resp = outcome_response(&r.outcome, pending.degraded, pending.epoch);
        // try_send into the capacity-1 slot: never blocks the responder;
        // a gone client (disconnect, reply timeout) makes this a no-op.
        let _ = pending.reply.try_send(ResponseFrame {
            id: pending.request_id,
            resp,
        });
        true
    }

    /// Outstanding registrations (tests; leak detection).
    pub fn pending(&self) -> usize {
        self.routes.lock().unwrap_or_else(|p| p.into_inner()).len()
    }
}

/// The responder-stage tap that feeds a router (see
/// [`Pipeline::start_with_tap`]).
///
/// [`Pipeline::start_with_tap`]: crate::coordinator::pipeline::Pipeline::start_with_tap
pub fn result_tap(router: &Arc<ResultRouter>) -> ResultTap {
    let router = Arc::clone(router);
    Arc::new(move |r| {
        router.deliver(r);
    })
}

fn outcome_response(outcome: &Outcome, degraded: bool, epoch: u64) -> Response {
    match outcome {
        Outcome::Score(s) => Response::Score {
            score: *s,
            degraded,
        },
        Outcome::TopK(ranked) => Response::TopK {
            ranked: ranked.clone(),
            degraded,
            epoch,
        },
        Outcome::Rejected(reason) => Response::Error {
            code: "rejected".into(),
            detail: reason.to_string(),
        },
        Outcome::EngineError(err) => Response::Error {
            code: "engine".into(),
            detail: err.to_string(),
        },
    }
}

/// The front-stage loop: dequeue admitted frames, shed the stale,
/// degrade under load, submit the rest. Exits when every connection
/// thread (sender) is gone; drops its [`SubmitHandle`] on exit so
/// [`Pipeline::finish`] can start the stage cascade.
///
/// [`Pipeline::finish`]: crate::coordinator::pipeline::Pipeline::finish
pub fn front_stage(
    rx: NamedReceiver<AdmittedFrame>,
    submit: SubmitHandle,
    router: Arc<ResultRouter>,
    corpora: BTreeMap<String, Arc<CorpusStore>>,
    signal: Arc<LoadSignal>,
    counters: Arc<NetCounters>,
    model: ModelConfig,
    cfg: NetConfig,
    recorder: Option<Arc<TraceRecorder>>,
) {
    let stats = rx.stats();
    let cap = stats.capacity().max(1);
    while let Ok(frame) = rx.recv() {
        let AdmittedFrame {
            client,
            request_id,
            req,
            deadline,
            reply: reply_tx,
        } = frame;
        let reply = |resp: Response| {
            let _ = reply_tx.try_send(ResponseFrame {
                id: request_id,
                resp,
            });
        };
        // Shed at dequeue: the frame's wait already exceeded its
        // deadline, so the client has (or should have) given up —
        // engine time goes to frames that can still be answered in
        // time. note_shed keeps the channel's ledger honest: the frame
        // was sent and delivered, just never processed.
        if Instant::now() > deadline {
            stats.note_shed();
            counters.note_shed_deadline();
            reply(Response::Error {
                code: "deadline".into(),
                detail: format!("shed: queued past the {} ms deadline", cfg.deadline_ms),
            });
            continue;
        }
        // Shape gate: the wire codec's MAX_WIRE_NODES only protects the
        // decoder; what every scoring lane requires is the model's
        // n_max / num_labels (router::validate_graph — the same check
        // the in-process admission stage applies). Enforced here,
        // before lane selection, so the degraded GED fallback (O(n^3),
        // on this single thread) can never run on a graph the engine
        // path would reject with TooManyNodes — a hostile 4096-node
        // Pair must not stall the sole admission consumer, nor earn a
        // fabricated score for a query the normal path refuses.
        let shape_err = match &req {
            Request::Hello => None,
            Request::Pair { g1, g2 } => validate_graph(&model, g1)
                .and_then(|()| validate_graph(&model, g2))
                .err(),
            Request::TopK { graph, .. } => validate_graph(&model, graph).err(),
            Request::Upsert { graph, .. } => validate_graph(&model, graph).err(),
            Request::Remove { .. } => None,
        };
        if let Some(reason) = shape_err {
            // Same code + detail the pipeline's Outcome::Rejected maps
            // to, so clients can't tell which layer refused.
            reply(Response::Error {
                code: "rejected".into(),
                detail: reason.to_string(),
            });
            continue;
        }
        // Trace tap, after the shape gate and before lane selection: the
        // trace holds exactly the admitted, servable workload — including
        // pairs the degraded GED lane answers below, which are admitted
        // work even though no engine sees them (DESIGN.md S19). Record
        // methods latch failures internally and never panic or block
        // beyond one short uncontended lock.
        if let Some(rec) = &recorder {
            // TopK is recorded inside its dispatch arm below, where the
            // snapshot epoch is in hand; mutations are not scoring
            // workload and stay out of the trace.
            if let Request::Pair { g1, g2 } = &req {
                rec.record_pair(&client, request_id, g1, g2);
            }
        }
        // Load signal: queue depth right after this dequeue, as a
        // fraction of capacity. Sampled per frame, smoothed by the
        // EWMA, hysteresis in the signal keeps the mode from flapping.
        let degraded = signal.observe(stats.depth() as f64 / cap as f64);
        match req {
            Request::Hello => {
                // Answered at the connection layer; a Hello that reaches
                // the queue is a protocol misuse, answered typed.
                reply(Response::Error {
                    code: "protocol".into(),
                    detail: "hello is answered at the connection layer".into(),
                });
            }
            Request::Pair { ref g1, ref g2 } if degraded && cfg.ged_fallback => {
                // Degraded pair lane: the greedy GED upper bound and the
                // paper's normalized-similarity map (Eq. 1), no engine
                // time at all. Marked on the response and counted.
                let sim = ged_similarity(greedy_ged(g1, g2), g1.num_nodes(), g2.num_nodes());
                counters.note_degraded();
                reply(Response::Score {
                    score: sim as f32,
                    degraded: true,
                });
            }
            Request::Pair { g1, g2 } => {
                let internal = router.register(request_id, false, 0, reply_tx.clone());
                if !submit.submit(Query::new(internal, g1, g2)) {
                    router.cancel(internal);
                    reply(Response::Error {
                        code: "shutting_down".into(),
                        detail: "pipeline is shutting down".into(),
                    });
                }
            }
            Request::TopK {
                corpus,
                graph,
                k,
                budget,
            } => {
                let Some(store) = corpora.get(&corpus) else {
                    reply(Response::Error {
                        code: "unknown_corpus".into(),
                        detail: format!(
                            "no corpus '{corpus}' registered (hello lists them)"
                        ),
                    });
                    continue;
                };
                // Snapshot exactly once at admission: the query, the
                // response epoch, and the trace line all name the same
                // corpus generation, no matter what upserts land while
                // the query is in flight.
                let snap = store.snapshot();
                if let Some(rec) = &recorder {
                    rec.record_topk(&client, request_id, &graph, &corpus, k, snap.epoch, budget);
                }
                // Degraded top-k: shrink the candidate depth the client
                // pays for; the ranking head stays engine-accurate.
                let (k_eff, shrunk) = if degraded && k > cfg.degraded_topk.max(1) {
                    (cfg.degraded_topk.max(1), true)
                } else {
                    (k, false)
                };
                if shrunk {
                    counters.note_degraded();
                }
                let mode = if budget > 0 {
                    CascadeMode::Budgeted { budget }
                } else {
                    CascadeMode::Exact
                };
                let internal = router.register(request_id, shrunk, snap.epoch, reply_tx.clone());
                if !submit.submit(Query::topk_with(
                    internal,
                    graph,
                    Arc::clone(&snap.corpus),
                    k_eff,
                    mode,
                )) {
                    router.cancel(internal);
                    reply(Response::Error {
                        code: "shutting_down".into(),
                        detail: "pipeline is shutting down".into(),
                    });
                }
            }
            Request::Upsert { corpus, id, graph } => {
                let Some(store) = corpora.get(&corpus) else {
                    reply(Response::Error {
                        code: "unknown_corpus".into(),
                        detail: format!(
                            "no corpus '{corpus}' registered (hello lists them)"
                        ),
                    });
                    continue;
                };
                // Mutations are answered here, never submitted: the
                // store swaps a fresh snapshot and in-flight queries
                // keep the one they admitted against.
                match store.upsert(id, graph) {
                    Ok(o) => reply(Response::Mutated {
                        epoch: o.epoch,
                        size: o.size,
                    }),
                    Err(e) => reply(Response::Error {
                        code: "rejected".into(),
                        detail: e.to_string(),
                    }),
                }
            }
            Request::Remove { corpus, id } => {
                let Some(store) = corpora.get(&corpus) else {
                    reply(Response::Error {
                        code: "unknown_corpus".into(),
                        detail: format!(
                            "no corpus '{corpus}' registered (hello lists them)"
                        ),
                    });
                    continue;
                };
                match store.remove(id) {
                    Ok(o) => reply(Response::Mutated {
                        epoch: o.epoch,
                        size: o.size,
                    }),
                    Err(e) => reply(Response::Error {
                        code: "rejected".into(),
                        detail: e.to_string(),
                    }),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_bucket_burst_then_throttle() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(10.0, 3.0, t0);
        // Burst capacity is honored...
        assert!(b.try_take(t0).is_ok());
        assert!(b.try_take(t0).is_ok());
        assert!(b.try_take(t0).is_ok());
        // ...then the empty bucket names a finite, rate-shaped retry.
        let retry = b.try_take(t0).unwrap_err();
        assert!(retry > Duration::ZERO && retry <= Duration::from_millis(100));
        // Refill: 10 tokens/s means 0.2 s buys two more requests.
        let later = t0 + Duration::from_millis(200);
        assert!(b.try_take(later).is_ok());
        assert!(b.try_take(later).is_ok());
        assert!(b.try_take(later).is_err());
    }

    #[test]
    fn token_bucket_never_exceeds_burst() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(1000.0, 2.0, t0);
        // A long idle period must not bank unlimited tokens.
        let later = t0 + Duration::from_secs(3600);
        assert!(b.try_take(later).is_ok());
        assert!(b.try_take(later).is_ok());
        assert!(b.try_take(later).is_err());
    }

    #[test]
    fn zero_rate_bucket_reports_clamped_retry() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(0.0, 1.0, t0);
        assert!(b.try_take(t0).is_ok());
        let retry = b.try_take(t0).unwrap_err();
        assert_eq!(retry, Duration::from_secs(60), "infinite wait clamps to 60s");
    }

    #[test]
    fn bucket_table_bounds_distinct_clients() {
        let cfg = NetConfig {
            refill_per_s: 0.0,
            burst: 1.0,
            max_clients: 2,
            ..NetConfig::default()
        };
        let table = BucketTable::new(&cfg);
        assert!(table.admit("a").is_ok());
        assert!(table.admit("b").is_ok());
        // Table full: client "c" lands in the anonymous bucket...
        assert!(table.admit("c").is_ok());
        assert_eq!(table.tracked(), 3, "a, b and the shared anonymous bucket");
        // ...which "d" then shares (and finds empty).
        assert!(table.admit("d").is_err());
        // Known clients keep their own (empty) buckets.
        assert!(table.admit("a").is_err());
        assert_eq!(table.tracked(), 3);
    }

    #[test]
    fn load_signal_hysteresis() {
        let s = LoadSignal::new(0.5, 0.2);
        assert!(!s.is_degraded());
        // Sustained full-queue observations engage the mode.
        let mut engaged = false;
        for _ in 0..30 {
            engaged = s.observe(1.0);
        }
        assert!(engaged && s.is_degraded());
        assert!(s.ewma() > 0.9);
        // One quiet sample does NOT disengage (hysteresis)...
        assert!(s.observe(0.0), "ewma still above lo");
        // ...but a sustained quiet period does.
        for _ in 0..30 {
            s.observe(0.0);
        }
        assert!(!s.is_degraded());
        // And re-engaging needs hi again, not lo.
        s.observe(0.3);
        assert!(!s.is_degraded());
    }

    #[test]
    fn router_delivers_by_internal_id_and_echoes_client_id() {
        let router = ResultRouter::new();
        let (tx, rx) = std::sync::mpsc::sync_channel(1);
        let internal = router.register(777, true, 0, tx);
        assert_eq!(router.pending(), 1);
        let g = crate::graph::Graph::new(1, vec![], vec![0]);
        let q = Query::new(internal, g.clone(), g);
        let mut result = QueryResult::rejected(&q, crate::coordinator::query::RejectReason::ShuttingDown);
        result.outcome = Outcome::Score(0.25);
        assert!(router.deliver(&result));
        assert_eq!(router.pending(), 0, "delivery consumes the route");
        let frame = rx.try_recv().unwrap();
        assert_eq!(frame.id, 777, "client correlation id echoed");
        assert_eq!(
            frame.resp,
            Response::Score {
                score: 0.25,
                degraded: true
            }
        );
        // Unknown ids (in-process traffic) are not the router's.
        assert!(!router.deliver(&result));
    }

    #[test]
    fn router_survives_dropped_receiver() {
        let router = ResultRouter::new();
        let (tx, rx) = std::sync::mpsc::sync_channel(1);
        let internal = router.register(1, false, 0, tx);
        drop(rx); // client disconnected mid-flight
        let g = crate::graph::Graph::new(1, vec![], vec![0]);
        let q = Query::new(internal, g.clone(), g);
        let mut result = QueryResult::rejected(&q, crate::coordinator::query::RejectReason::ShuttingDown);
        result.outcome = Outcome::Score(0.5);
        // Delivery is a no-op send, not a panic or a block.
        assert!(router.deliver(&result));
        assert_eq!(router.pending(), 0);
    }

    #[test]
    fn outcome_mapping_is_typed() {
        use crate::runtime::EngineError;
        match outcome_response(&Outcome::Rejected(
            crate::coordinator::query::RejectReason::EmptyCorpus,
        ), false, 0) {
            Response::Error { code, .. } => assert_eq!(code, "rejected"),
            other => panic!("{other:?}"),
        }
        match outcome_response(
            &Outcome::EngineError(EngineError::Unavailable { reason: "x".into() }),
            false,
            0,
        ) {
            Response::Error { code, .. } => assert_eq!(code, "engine"),
            other => panic!("{other:?}"),
        }
        match outcome_response(&Outcome::TopK(vec![(1, 0.5)]), true, 9) {
            Response::TopK {
                ranked,
                degraded,
                epoch,
            } => {
                assert_eq!(ranked, vec![(1, 0.5)]);
                assert!(degraded);
                assert_eq!(epoch, 9, "admission-time snapshot epoch echoed");
            }
            other => panic!("{other:?}"),
        }
    }
}
