//! SPA-GCN: efficient and flexible GCN accelerator for small graphs, with
//! a SimGNN graph-similarity serving application.
//!
//! Reproduction of Sohrabizadeh, Chi & Cong (2021) as a three-layer
//! rust + JAX + Pallas system — see DESIGN.md for the architecture map.

// The tree is unsafe-free and must stay that way: every kernel,
// including the vectorized lanes path, is safe Rust (DESIGN.md S16/S18).
#![forbid(unsafe_code)]
// Every public type prints: engines, configs, metrics and wire frames
// all land in logs and test failures, so Debug is part of the API.
#![deny(missing_debug_implementations)]

pub mod analysis;
pub mod coordinator;
pub mod ged;
pub mod graph;
pub mod net;
pub mod nn;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;
