//! SPA-GCN: efficient and flexible GCN accelerator for small graphs, with
//! a SimGNN graph-similarity serving application.
//!
//! Reproduction of Sohrabizadeh, Chi & Cong (2021) as a three-layer
//! rust + JAX + Pallas system — see DESIGN.md for the architecture map.
pub mod coordinator;
pub mod ged;
pub mod graph;
pub mod net;
pub mod nn;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;
