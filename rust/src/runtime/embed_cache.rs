//! Sharded LRU cache of graph-level embeddings, keyed by the content
//! fingerprint of `(labels, edges)` (`graph::encode::GraphKey`).
//!
//! The SimGNN forward splits into a per-graph stage (GCN + attention —
//! all the heavy work) and a per-pair tail (NTN + FCN). The per-graph
//! stage depends only on the graph itself, so a one-vs-many corpus query
//! of K candidates needs exactly `unique_graphs` GCN forwards, not K —
//! the same redundancy elimination GraphACT applies to repeated
//! aggregations before they reach the accelerator. Engines consult this
//! cache before every embed; hit/miss counts ride out per query as
//! [`QueryTelemetry::embed_cache`](super::QueryTelemetry) and surface in
//! the serve report (`embed cache hit rate` / `embed cache entries` /
//! `gcn forwards per query`). See DESIGN.md S14.
//!
//! Sharding bounds lock hold times when a cache is shared (the cache is
//! interior-mutable — `get`/`insert` take `&self`); LRU order is
//! therefore *per shard*. Tests that need strict global LRU semantics
//! construct a single-shard cache via [`EmbedCache::with_shards`].

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::graph::encode::GraphKey;

use super::MacCounts;

/// Default entry capacity for engine-owned caches: at 16 f32s per
/// embedding this is well under a megabyte, yet covers a corpus far
/// larger than the synthetic workloads' 512-graph database.
pub const DEFAULT_CAPACITY: usize = 4096;

/// Default shard count for engine-owned caches.
pub const DEFAULT_SHARDS: usize = 8;

/// One cached per-graph result: the post-attention embedding plus the
/// GCN work counts that produced it (so reports can price what a hit
/// saves without recomputing anything). Entries live behind `Arc` so a
/// hit is a pointer clone — no `hg` allocation under the shard lock.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CachedEmbed {
    /// Post-attention graph embedding, `embed_dim()` floats.
    pub hg: Vec<f32>,
    /// GCN-stage work executed to produce `hg` (one graph's share).
    pub macs: MacCounts,
}

/// Aggregate cache counters (monotonic except `entries`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// `get` calls that found an entry.
    pub hits: u64,
    /// `get` calls that missed.
    pub misses: u64,
    /// Entries displaced by capacity pressure.
    pub evictions: u64,
    /// Current entry count across all shards.
    pub entries: u64,
}

/// One shard: key -> (recency tick, value) plus a tick-ordered index for
/// O(log n) LRU eviction without unsafe pointer chasing.
#[derive(Debug)]
struct Shard {
    map: HashMap<u128, (u64, Arc<CachedEmbed>)>,
    lru: BTreeMap<u64, u128>,
    tick: u64,
    capacity: usize,
}

impl Shard {
    fn new(capacity: usize) -> Self {
        Shard {
            map: HashMap::with_capacity(capacity.min(64)),
            lru: BTreeMap::new(),
            tick: 0,
            capacity,
        }
    }

    fn touch(&mut self, key: u128) -> Option<Arc<CachedEmbed>> {
        let entry = self.map.get_mut(&key)?;
        let old = entry.0;
        self.tick += 1;
        entry.0 = self.tick;
        let value = Arc::clone(&entry.1);
        self.lru.remove(&old);
        self.lru.insert(self.tick, key);
        Some(value)
    }

    /// Insert (or refresh) `key`; returns `(grew, evicted)`.
    fn insert(&mut self, key: u128, value: Arc<CachedEmbed>) -> (bool, bool) {
        self.tick += 1;
        if let Some((old, _)) = self.map.insert(key, (self.tick, value)) {
            // Refresh of an existing key: no growth, no eviction.
            self.lru.remove(&old);
            self.lru.insert(self.tick, key);
            return (false, false);
        }
        self.lru.insert(self.tick, key);
        let mut evicted = false;
        if self.map.len() > self.capacity {
            let (&oldest, &victim) = self.lru.iter().next().expect("non-empty over capacity");
            self.lru.remove(&oldest);
            self.map.remove(&victim);
            evicted = true;
        }
        (true, evicted)
    }
}

/// Sharded LRU embedding cache. `get`/`insert` are `&self` (a mutex per
/// shard), so an engine can consult its cache from `&self` accessors
/// and one cache can be shared across same-kind lanes behind an `Arc`
/// (injected through `EngineBuilder::with_embed_cache` — DESIGN.md
/// S15): corpus candidates warmed by one lane hit on every sibling.
#[derive(Debug)]
pub struct EmbedCache {
    shards: Vec<Mutex<Shard>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    entries: AtomicU64,
}

impl EmbedCache {
    /// Cache with ~`capacity` entries total (>= 1) across up to
    /// [`DEFAULT_SHARDS`] shards — the shard count clamps down so any
    /// positive capacity is valid.
    pub fn new(capacity: usize) -> Self {
        Self::with_shards(capacity, DEFAULT_SHARDS.min(capacity.max(1)))
    }

    /// Cache with an explicit shard count (tests use 1 shard for strict
    /// global LRU order). Total capacity splits evenly across shards,
    /// at least one entry each.
    pub fn with_shards(capacity: usize, shards: usize) -> Self {
        assert!(shards >= 1, "cache needs at least one shard");
        assert!(capacity >= shards, "capacity must cover every shard");
        let per_shard = capacity / shards;
        EmbedCache {
            shards: (0..shards).map(|_| Mutex::new(Shard::new(per_shard))).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            entries: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: GraphKey) -> &Mutex<Shard> {
        // Fold the 128-bit fingerprint; the key is already uniform.
        let folded = (key.0 as u64) ^ ((key.0 >> 64) as u64);
        &self.shards[(folded % self.shards.len() as u64) as usize]
    }

    /// Look up `key`, refreshing its recency. Counts a hit or a miss.
    /// A hit clones only the `Arc`, never the embedding.
    pub fn get(&self, key: GraphKey) -> Option<Arc<CachedEmbed>> {
        let hit = self.shard(key).lock().expect("embed cache poisoned").touch(key.0);
        match &hit {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        hit
    }

    /// Insert `key`, evicting the shard's least-recently-used entry when
    /// the shard is full.
    pub fn insert(&self, key: GraphKey, value: Arc<CachedEmbed>) {
        let (grew, evicted) = self
            .shard(key)
            .lock()
            .expect("embed cache poisoned")
            .insert(key.0, value);
        if evicted {
            self.evictions.fetch_add(1, Ordering::Relaxed);
        } else if grew {
            self.entries.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Current entry count across all shards.
    pub fn len(&self) -> usize {
        self.entries.load(Ordering::Relaxed) as usize
    }

    /// True when no entry is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Counter snapshot.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: self.entries.load(Ordering::Relaxed),
        }
    }
}

impl std::fmt::Debug for EmbedCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EmbedCache")
            .field("shards", &self.shards.len())
            .field("stats", &self.stats())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn key(v: u128) -> GraphKey {
        GraphKey(v)
    }

    fn embed(tag: f32) -> Arc<CachedEmbed> {
        Arc::new(CachedEmbed {
            hg: vec![tag; 4],
            macs: MacCounts {
                macs: tag as u64,
                ft_elements: 1,
                agg_elements: 1,
            },
        })
    }

    #[test]
    fn lru_evicts_oldest_and_touch_refreshes() {
        // Single shard: strict global LRU order.
        let c = EmbedCache::with_shards(3, 1);
        for v in 1..=3u128 {
            c.insert(key(v), embed(v as f32));
        }
        // Touch 1 so 2 becomes the oldest, then overflow.
        assert!(c.get(key(1)).is_some());
        c.insert(key(4), embed(4.0));
        assert!(c.get(key(2)).is_none(), "LRU victim must be the untouched 2");
        for v in [1u128, 3, 4] {
            assert!(c.get(key(v)).is_some(), "entry {v} wrongly evicted");
        }
        assert_eq!(c.len(), 3);
        assert_eq!(c.stats().evictions, 1);
        // Eviction follows recency, not insertion: the verification gets
        // above touched 1, 3, 4 in that order, so 1 is now the oldest.
        c.insert(key(5), embed(5.0));
        assert!(c.get(key(1)).is_none(), "second victim follows touch order");
    }

    #[test]
    fn reinsert_refreshes_without_growth() {
        let c = EmbedCache::with_shards(2, 1);
        c.insert(key(1), embed(1.0));
        c.insert(key(2), embed(2.0));
        // Refreshing 1 must not evict and must update the stored value.
        c.insert(key(1), embed(10.0));
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().evictions, 0);
        assert_eq!(c.get(key(1)).unwrap().hg, vec![10.0; 4]);
        // 2 is now the LRU victim despite being inserted after 1.
        c.insert(key(3), embed(3.0));
        assert!(c.get(key(2)).is_none());
    }

    #[test]
    fn tiny_capacities_construct_and_evict() {
        // new() clamps the shard count, so capacities below the default
        // shard count are valid.
        let c = EmbedCache::new(2);
        for v in 1..=5u128 {
            c.insert(key(v), embed(v as f32));
        }
        assert!(c.len() <= 2);
        assert!(c.stats().evictions >= 3);
    }

    #[test]
    fn hit_miss_accounting_is_exact() {
        let c = EmbedCache::with_shards(4, 1);
        assert!(c.get(key(9)).is_none());
        c.insert(key(9), embed(9.0));
        assert!(c.get(key(9)).is_some());
        assert!(c.get(key(9)).is_some());
        assert!(c.get(key(8)).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (2, 2, 1));
    }

    #[test]
    fn shared_cache_concurrent_accounting_stays_exact() {
        // The cache is now shared across executor lanes (DESIGN.md
        // S15), so the accounting must survive real contention, not
        // just the single-threaded paths the other tests drive. Hammer
        // `get`/`insert` from N threads and check the counters add up
        // exactly afterwards — and that `len() <= capacity` holds at
        // every moment any thread observes it.
        use std::sync::Arc;
        use std::thread;
        const THREADS: u64 = 4;
        const OPS: u64 = 2000;
        const KEYS: usize = 48;
        // Two regimes: ample capacity (no evictions — entry count must
        // equal the distinct keys touched) and tight capacity (evictions
        // churn — the capacity bound and the get accounting still hold).
        for capacity in [1024usize, 16] {
            let cache = Arc::new(EmbedCache::with_shards(capacity, DEFAULT_SHARDS));
            let handles: Vec<thread::JoinHandle<u64>> = (0..THREADS)
                .map(|t| {
                    let cache = Arc::clone(&cache);
                    thread::spawn(move || {
                        let mut rng = Rng::new(1000 + t);
                        let mut gets = 0u64;
                        for _ in 0..OPS {
                            let k = key(rng.below(KEYS) as u128 + 1);
                            if rng.below(2) == 0 {
                                cache.insert(k, embed(t as f32));
                            } else {
                                let _ = cache.get(k);
                                gets += 1;
                            }
                            assert!(
                                cache.len() <= capacity,
                                "len {} > capacity {capacity} under contention",
                                cache.len()
                            );
                        }
                        gets
                    })
                })
                .collect();
            let total_gets: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
            let s = cache.stats();
            assert_eq!(
                s.hits + s.misses,
                total_gets,
                "a get must count exactly one hit or miss (capacity {capacity})"
            );
            assert_eq!(s.entries as usize, cache.len());
            assert!(cache.len() <= capacity);
            if capacity >= KEYS {
                // Ample: nothing may be displaced, and every distinct
                // key some thread inserted is resident. Every key in
                // 1..=KEYS is eventually inserted with overwhelming
                // probability (4×2000 draws over 48 keys), but assert
                // only what is certain: entries == distinct keys seen.
                assert_eq!(s.evictions, 0, "ample cache must not evict");
                let resident = (1..=KEYS as u128).filter(|&v| cache.get(key(v)).is_some()).count();
                assert_eq!(resident, s.entries as usize);
            } else {
                assert!(s.evictions > 0, "tight cache must have churned");
            }
        }
    }

    #[test]
    fn capacity_property_random_ops() {
        // Property: len() never exceeds capacity, the most recently
        // inserted key is always resident, and hits + misses equals the
        // number of gets — across shard counts.
        for shards in [1usize, 4] {
            let capacity = 16;
            let c = EmbedCache::with_shards(capacity, shards);
            let mut rng = Rng::new(41 + shards as u64);
            let mut gets = 0u64;
            for step in 0..2000u128 {
                let k = key(rng.below(64) as u128 * 7 + (step % 3));
                if rng.below(2) == 0 {
                    c.insert(k, embed(step as f32));
                    assert!(
                        c.get(k).is_some(),
                        "just-inserted key missing (shards={shards}, step={step})"
                    );
                    gets += 1;
                } else {
                    let _ = c.get(k);
                    gets += 1;
                }
                assert!(c.len() <= capacity, "len {} > capacity", c.len());
            }
            let s = c.stats();
            assert_eq!(s.hits + s.misses, gets);
            assert_eq!(s.entries as usize, c.len());
            assert!(s.entries > 0);
        }
    }
}
