//! Native engine: the independent rust SimGNN numerics as an execution
//! backend. Serves two purposes:
//!  * correctness cross-check against the PJRT engine (same scores ±1e-4);
//!  * the measured per-stage CPU baseline used alongside the analytical
//!    PyG model in the Table 6 reproduction.
//!
//! Scoring defaults to the sparse path ([`SparsePolicy::Csr`]: CSR
//! aggregation, one-hot layer-0 FT, nonzero-skipping FT, real rows only
//! — DESIGN.md S13); `with_policy(SparsePolicy::Dense)` forces the dense
//! padded baseline for comparison runs (`EngineKind::NativeDense`).

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::graph::encode::{EncodedGraph, PackedBatch};
use crate::nn::config::{ArtifactsMeta, ModelConfig, AOT_BATCH_LADDER};
use crate::nn::simgnn::{simgnn_forward_with, SparsePolicy};
use crate::nn::weights::Weights;

use super::{BatchOutput, Engine, EngineCaps, EngineError, MacCounts, QueryTelemetry};

/// CPU reference engine; any batch size (it just loops over pairs).
/// Reports per-slot CPU time as [`QueryTelemetry::cpu_us`] and MAC /
/// nonzero work counts as [`QueryTelemetry::macs`].
pub struct NativeEngine {
    cfg: ModelConfig,
    weights: Weights,
    caps: EngineCaps,
    policy: SparsePolicy,
}

impl NativeEngine {
    /// Load config + weights from an artifacts directory. The advertised
    /// batch ladder comes from `meta.json` — the same source the PJRT
    /// engine compiles from — so the two can never drift.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let meta = ArtifactsMeta::load(artifacts_dir)
            .context("loading artifacts/meta.json (run `make artifacts`)")?;
        let weights = Weights::load(&meta.config, artifacts_dir)?;
        Ok(Self::from_parts(meta.config, weights, meta.batch_sizes))
    }

    /// Build from an in-memory config + weights (tests, report harness);
    /// advertises the shared [`AOT_BATCH_LADDER`].
    pub fn new(cfg: ModelConfig, weights: Weights) -> Self {
        Self::from_parts(cfg, weights, AOT_BATCH_LADDER.to_vec())
    }

    fn from_parts(cfg: ModelConfig, weights: Weights, ladder: Vec<usize>) -> Self {
        let caps = EngineCaps::new("native-cpu", ladder, cfg.n_max, cfg.num_labels)
            .with_mac_counts();
        NativeEngine {
            cfg,
            weights,
            caps,
            policy: SparsePolicy::Csr,
        }
    }

    /// Force a scoring path. The dense variant renames the engine to
    /// `native-cpu-dense` so reports and metrics keep the lanes apart.
    pub fn with_policy(mut self, policy: SparsePolicy) -> Self {
        self.policy = policy;
        self.caps.name = match policy {
            SparsePolicy::Csr => "native-cpu".into(),
            SparsePolicy::Dense => "native-cpu-dense".into(),
        };
        self
    }

    /// The model configuration this engine scores with.
    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// The loaded model weights.
    pub fn weights(&self) -> &Weights {
        &self.weights
    }

    /// The scoring path this engine takes.
    pub fn policy(&self) -> SparsePolicy {
        self.policy
    }

    /// Score a single encoded pair (no batch packing needed).
    pub fn score_pair(&self, g1: &EncodedGraph, g2: &EncodedGraph) -> f32 {
        simgnn_forward_with(&self.cfg, &self.weights, g1, g2, self.policy).score
    }
}

impl Engine for NativeEngine {
    fn caps(&self) -> &EngineCaps {
        &self.caps
    }

    fn score_batch(&mut self, batch: &PackedBatch) -> Result<BatchOutput, EngineError> {
        let mut scores = Vec::with_capacity(batch.batch);
        let mut telemetry = Vec::with_capacity(batch.batch);
        for i in 0..batch.batch {
            let (g1, g2) = batch.unpack_slot(i).map_err(|e| EngineError::InvalidInput {
                detail: format!("slot {i}: {e}"),
            })?;
            // Empty padding slots: mask is all-zero; score is well-defined
            // (sigmoid of bias path) and discarded by the caller.
            let t0 = Instant::now();
            let trace = simgnn_forward_with(&self.cfg, &self.weights, &g1, &g2, self.policy);
            let cpu_us = t0.elapsed().as_secs_f64() * 1e6;
            scores.push(trace.score);
            let (t1, t2) = (&trace.trace1, &trace.trace2);
            telemetry.push(QueryTelemetry {
                cpu_us: Some(cpu_us),
                macs: Some(MacCounts {
                    macs: t1.macs + t2.macs,
                    ft_elements: t1.ft_elements.iter().sum::<u64>()
                        + t2.ft_elements.iter().sum::<u64>(),
                    agg_elements: t1.agg_elements + t2.agg_elements,
                }),
                ..QueryTelemetry::default()
            });
        }
        Ok(BatchOutput { scores, telemetry })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::encode::{encode, PackedBatch};
    use crate::graph::generate::{generate, Family};
    use crate::nn::simgnn::simgnn_score;
    use crate::util::rng::Rng;

    fn tiny() -> NativeEngine {
        let cfg = ModelConfig {
            n_max: 8,
            num_labels: 4,
            filters: [4, 4, 4],
            relu_mask: [true, true, false],
            ntn_k: 4,
            fc_dims: vec![4],
            seed: 0,
        };
        // deterministic pseudo-random weights
        let mut rng = Rng::new(99);
        let mut rand_vec = |len: usize| -> Vec<f32> {
            (0..len).map(|_| (rng.f32() - 0.5) * 0.6).collect()
        };
        let w = Weights {
            gcn_w: [rand_vec(4 * 4), rand_vec(4 * 4), rand_vec(4 * 4)],
            gcn_b: [vec![0.1; 4], vec![0.1; 4], vec![0.1; 4]],
            att_w: rand_vec(16),
            ntn_w: rand_vec(4 * 16),
            ntn_v: rand_vec(4 * 8),
            ntn_b: vec![0.0; 4],
            fc_w: vec![rand_vec(16)],
            fc_b: vec![vec![0.0; 4]],
            out_w: rand_vec(4),
            out_b: vec![0.0],
        };
        // note: gcn_w0 must be (num_labels=4, f1=4): 16 elements — ok.
        NativeEngine::new(cfg, w)
    }

    fn workload(count: usize, seed: u64) -> Vec<(EncodedGraph, EncodedGraph)> {
        let mut rng = Rng::new(seed);
        let f = Family::ErdosRenyi { n: 6, p_millis: 300 };
        (0..count)
            .map(|_| {
                let g1 = generate(&mut rng, f, 8, 4);
                let g2 = generate(&mut rng, f, 8, 4);
                (encode(&g1, 8, 4).unwrap(), encode(&g2, 8, 4).unwrap())
            })
            .collect()
    }

    #[test]
    fn batch_matches_per_pair() {
        let mut eng = tiny();
        let pairs = workload(3, 7);
        let pb = PackedBatch::pack(&pairs, 4).unwrap();
        let out = eng.score_batch(&pb).unwrap();
        assert_eq!(out.scores.len(), 4);
        assert_eq!(out.telemetry.len(), 4);
        for (i, (g1, g2)) in pairs.iter().enumerate() {
            let want = simgnn_score(eng.config(), eng.weights(), g1, g2);
            assert!((out.scores[i] - want).abs() < 1e-6);
        }
        // Per-slot CPU time and MAC counts are reported on every slot.
        assert!(out.telemetry.iter().all(|t| t.cpu_us.is_some()));
        assert!(out.telemetry.iter().all(|t| t.macs.is_some()));
        assert!(out.telemetry.iter().all(|t| t.cycles.is_none() && t.exec.is_none()));
        // Real slots did real work; the padding slot has no nonzeros to
        // process on the sparse path (0-node graphs).
        assert!(out.telemetry[0].macs.unwrap().macs > 0);
        assert_eq!(out.telemetry[3].macs.unwrap().ft_elements, 0);
    }

    #[test]
    fn dense_and_sparse_policies_agree_on_batches() {
        // Engine-level dense↔sparse parity across every ladder size,
        // padded tail slots included (the acceptance bar is 1e-5; the
        // paths are in fact bit-identical by construction).
        let mut sparse = tiny();
        let mut dense = NativeEngine::new(sparse.cfg.clone(), sparse.weights.clone())
            .with_policy(SparsePolicy::Dense);
        assert_eq!(sparse.policy(), SparsePolicy::Csr);
        let ladder = sparse.caps().batch_ladder().to_vec();
        for (bi, &b) in ladder.iter().enumerate() {
            // Underfill by one where possible so tail padding is covered.
            let fill = if b > 1 { b - 1 } else { 1 };
            let pairs = workload(fill, 100 + bi as u64);
            let pb = PackedBatch::pack(&pairs, b).unwrap();
            let s = sparse.score_batch(&pb).unwrap();
            let d = dense.score_batch(&pb).unwrap();
            for (i, (ss, ds)) in s.scores.iter().zip(d.scores.iter()).enumerate() {
                assert!(
                    (ss - ds).abs() < 1e-5,
                    "batch {b} slot {i}: sparse {ss} vs dense {ds}"
                );
            }
            // The sparse path reports strictly less counted work.
            let sm = s.telemetry[0].macs.unwrap();
            let dm = d.telemetry[0].macs.unwrap();
            assert!(sm.macs < dm.macs, "sparse {sm:?} !< dense {dm:?}");
            assert!(sm.ft_elements < dm.ft_elements);
            assert!(sm.agg_elements < dm.agg_elements);
        }
    }

    #[test]
    fn caps_describe_the_cpu_profile() {
        let eng = tiny();
        let caps = eng.caps();
        assert_eq!(caps.name, "native-cpu");
        assert_eq!(caps.batch_ladder(), &AOT_BATCH_LADDER);
        assert_eq!(caps.max_nodes, 8);
        assert_eq!(caps.max_labels, 4);
        assert!(!caps.reports_cycles);
        assert!(!caps.reports_exec_timing);
        assert!(caps.reports_macs);
        // The dense comparison lane is named apart.
        let dense = tiny().with_policy(SparsePolicy::Dense);
        assert_eq!(dense.caps().name, "native-cpu-dense");
    }

    #[test]
    fn ladder_follows_meta_manifest() {
        // Both engines' ladders flow from one meta source: a manifest
        // with a custom artifact ladder yields caps advertising exactly
        // that ladder (the PJRT engine compiles one executable per entry
        // of the same list), and the meta-less default is the shared
        // AOT_BATCH_LADDER constant.
        let eng = tiny();
        let custom = NativeEngine::from_parts(
            eng.cfg.clone(),
            eng.weights.clone(),
            vec![1, 8],
        );
        assert_eq!(custom.caps().batch_ladder(), &[1, 8]);
        let meta_doc = crate::util::json::parse(
            r#"{"config": {"filters": [4, 4, 4],
                "relu_mask": [true, true, false], "n_max": 8,
                "num_labels": 4, "ntn_k": 4, "fc_dims": [4]}}"#,
        )
        .unwrap();
        let meta = ArtifactsMeta::from_json(&meta_doc).unwrap();
        let from_meta = NativeEngine::from_parts(meta.config, eng.weights.clone(), meta.batch_sizes);
        assert_eq!(from_meta.caps().batch_ladder(), &AOT_BATCH_LADDER);
        assert_eq!(eng.caps().batch_ladder(), &AOT_BATCH_LADDER);
    }
}
