//! Native engine: the independent rust SimGNN numerics as an execution
//! backend. Serves two purposes:
//!  * correctness cross-check against the PJRT engine (same scores ±1e-4);
//!  * the measured per-stage CPU baseline used alongside the analytical
//!    PyG model in the Table 6 reproduction.

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::graph::encode::{EncodedGraph, PackedBatch};
use crate::nn::config::{ArtifactsMeta, ModelConfig};
use crate::nn::simgnn::simgnn_score;
use crate::nn::weights::Weights;

use super::{BatchOutput, Engine, EngineCaps, EngineError, QueryTelemetry};

/// CPU reference engine; any batch size (it just loops over pairs).
/// Reports per-slot CPU time as [`QueryTelemetry::cpu_us`].
pub struct NativeEngine {
    cfg: ModelConfig,
    weights: Weights,
    caps: EngineCaps,
}

impl NativeEngine {
    /// Load config + weights from an artifacts directory.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let meta = ArtifactsMeta::load(artifacts_dir)
            .context("loading artifacts/meta.json (run `make artifacts`)")?;
        let weights = Weights::load(&meta.config, artifacts_dir)?;
        Ok(Self::new(meta.config, weights))
    }

    /// Build from an in-memory config + weights (tests, report harness).
    pub fn new(cfg: ModelConfig, weights: Weights) -> Self {
        // The loop handles any size; advertise the same ladder as the AOT
        // artifacts so the batcher treats both engines identically.
        let caps = EngineCaps::new("native-cpu", vec![1, 4, 16, 64], cfg.n_max, cfg.num_labels);
        NativeEngine { cfg, weights, caps }
    }

    /// The model configuration this engine scores with.
    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// The loaded model weights.
    pub fn weights(&self) -> &Weights {
        &self.weights
    }

    /// Score a single encoded pair (no batch packing needed).
    pub fn score_pair(&self, g1: &EncodedGraph, g2: &EncodedGraph) -> f32 {
        simgnn_score(&self.cfg, &self.weights, g1, g2)
    }
}

impl Engine for NativeEngine {
    fn caps(&self) -> &EngineCaps {
        &self.caps
    }

    fn score_batch(&mut self, batch: &PackedBatch) -> Result<BatchOutput, EngineError> {
        let mut scores = Vec::with_capacity(batch.batch);
        let mut telemetry = Vec::with_capacity(batch.batch);
        for i in 0..batch.batch {
            let (g1, g2) = batch.unpack_slot(i);
            // Empty padding slots: mask is all-zero; score is well-defined
            // (sigmoid of bias path) and discarded by the caller.
            let t0 = Instant::now();
            scores.push(simgnn_score(&self.cfg, &self.weights, &g1, &g2));
            telemetry.push(QueryTelemetry {
                cpu_us: Some(t0.elapsed().as_secs_f64() * 1e6),
                ..QueryTelemetry::default()
            });
        }
        Ok(BatchOutput { scores, telemetry })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::encode::{encode, PackedBatch};
    use crate::graph::generate::{generate, Family};
    use crate::nn::simgnn::simgnn_score;
    use crate::util::rng::Rng;

    fn tiny() -> NativeEngine {
        let cfg = ModelConfig {
            n_max: 8,
            num_labels: 4,
            filters: [4, 4, 4],
            relu_mask: [true, true, false],
            ntn_k: 4,
            fc_dims: vec![4],
            seed: 0,
        };
        // deterministic pseudo-random weights
        let mut rng = Rng::new(99);
        let mut rand_vec = |len: usize| -> Vec<f32> {
            (0..len).map(|_| (rng.f32() - 0.5) * 0.6).collect()
        };
        let w = Weights {
            gcn_w: [rand_vec(4 * 4), rand_vec(4 * 4), rand_vec(4 * 4)],
            gcn_b: [vec![0.1; 4], vec![0.1; 4], vec![0.1; 4]],
            att_w: rand_vec(16),
            ntn_w: rand_vec(4 * 16),
            ntn_v: rand_vec(4 * 8),
            ntn_b: vec![0.0; 4],
            fc_w: vec![rand_vec(16)],
            fc_b: vec![vec![0.0; 4]],
            out_w: rand_vec(4),
            out_b: vec![0.0],
        };
        // note: gcn_w0 must be (num_labels=4, f1=4): 16 elements — ok.
        NativeEngine::new(cfg, w)
    }

    #[test]
    fn batch_matches_per_pair() {
        let mut eng = tiny();
        let mut rng = Rng::new(7);
        let f = Family::ErdosRenyi { n: 6, p_millis: 300 };
        let pairs: Vec<_> = (0..3)
            .map(|_| {
                let g1 = generate(&mut rng, f, 8, 4);
                let g2 = generate(&mut rng, f, 8, 4);
                (
                    encode(&g1, 8, 4).unwrap(),
                    encode(&g2, 8, 4).unwrap(),
                )
            })
            .collect();
        let pb = PackedBatch::pack(&pairs, 4);
        let out = eng.score_batch(&pb).unwrap();
        assert_eq!(out.scores.len(), 4);
        assert_eq!(out.telemetry.len(), 4);
        for (i, (g1, g2)) in pairs.iter().enumerate() {
            let want = simgnn_score(eng.config(), eng.weights(), g1, g2);
            assert!((out.scores[i] - want).abs() < 1e-6);
        }
        // Per-slot CPU time is reported on every slot.
        assert!(out.telemetry.iter().all(|t| t.cpu_us.is_some()));
        assert!(out.telemetry.iter().all(|t| t.cycles.is_none() && t.exec.is_none()));
    }

    #[test]
    fn caps_describe_the_cpu_profile() {
        let eng = tiny();
        let caps = eng.caps();
        assert_eq!(caps.name, "native-cpu");
        assert_eq!(caps.batch_ladder(), &[1, 4, 16, 64]);
        assert_eq!(caps.max_nodes, 8);
        assert_eq!(caps.max_labels, 4);
        assert!(!caps.reports_cycles);
        assert!(!caps.reports_exec_timing);
    }
}
