//! Native engine: the independent rust SimGNN numerics as an execution
//! backend. Serves two purposes:
//!  * correctness cross-check against the PJRT engine (same scores ±1e-4);
//!  * the measured per-stage CPU baseline used alongside the analytical
//!    PyG model in the Table 6 reproduction.
//!
//! Scoring defaults to the sparse path ([`SparsePolicy::Csr`]: CSR
//! aggregation, one-hot layer-0 FT, nonzero-skipping FT, real rows only
//! — DESIGN.md S13); `with_policy(SparsePolicy::Dense)` forces the dense
//! padded baseline for comparison runs (`EngineKind::NativeDense`).
//!
//! All scoring goes through the per-graph embedding cache (DESIGN.md
//! S14): each graph of a pair or corpus fan-out is fingerprinted and its
//! GCN+attention embedding reused when seen before — within a batch,
//! across queries, and across an entire corpus. Only the NTN+FCN tail
//! runs per pair. Scores are bit-identical to the uncached fused
//! forward because the split API *is* the fused forward.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::graph::encode::{EncodedGraph, PackedBatch};
use crate::nn::config::{ArtifactsMeta, ModelConfig, AOT_BATCH_LADDER};
use crate::nn::simgnn::{embed_graph_with, pair_score, SparsePolicy};
use crate::nn::weights::Weights;

use super::embed_cache::{CachedEmbed, EmbedCache};
use super::{
    BatchOutput, CorpusOutput, EmbedCacheTelemetry, Engine, EngineCaps, EngineError, MacCounts,
    QueryEmbed, QueryTelemetry,
};

/// CPU reference engine; any batch size (it just loops over pairs).
/// Reports per-slot CPU time as [`QueryTelemetry::cpu_us`], MAC /
/// nonzero work counts as [`QueryTelemetry::macs`] (executed work only —
/// cache hits contribute zero), and cache activity as
/// [`QueryTelemetry::embed_cache`].
#[derive(Debug)]
pub struct NativeEngine {
    cfg: ModelConfig,
    weights: Weights,
    caps: EngineCaps,
    policy: SparsePolicy,
    /// Behind `Arc` so same-kind lanes can serve from one shared cache
    /// (injected via `EngineBuilder::with_embed_cache`, DESIGN.md S15);
    /// a lone engine owns a private one.
    cache: Arc<EmbedCache>,
}

impl NativeEngine {
    /// Load config + weights from an artifacts directory. The advertised
    /// batch ladder comes from `meta.json` — the same source the PJRT
    /// engine compiles from — so the two can never drift.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let meta = ArtifactsMeta::load(artifacts_dir)
            .context("loading artifacts/meta.json (run `make artifacts`)")?;
        let weights = Weights::load(&meta.config, artifacts_dir)?;
        Ok(Self::from_parts(meta.config, weights, meta.batch_sizes))
    }

    /// Build from an in-memory config + weights (tests, report harness);
    /// advertises the shared [`AOT_BATCH_LADDER`].
    pub fn new(cfg: ModelConfig, weights: Weights) -> Self {
        Self::from_parts(cfg, weights, AOT_BATCH_LADDER.to_vec())
    }

    fn from_parts(cfg: ModelConfig, weights: Weights, ladder: Vec<usize>) -> Self {
        let caps = EngineCaps::new("native-cpu", ladder, cfg.n_max, cfg.num_labels)
            .with_mac_counts()
            .with_embed_cache()
            .with_corpus_scoring()
            .with_corpus_sharding();
        NativeEngine {
            cfg,
            weights,
            caps,
            policy: SparsePolicy::Csr,
            cache: Arc::new(EmbedCache::new(super::embed_cache::DEFAULT_CAPACITY)),
        }
    }

    /// Force a scoring path. The dense variant renames the engine to
    /// `native-cpu-dense` so reports and metrics keep the lanes apart.
    pub fn with_policy(mut self, policy: SparsePolicy) -> Self {
        self.policy = policy;
        self.caps.name = match policy {
            SparsePolicy::Csr => "native-cpu".into(),
            SparsePolicy::Dense => "native-cpu-dense".into(),
        };
        self
    }

    /// Serve from a shared embedding cache instead of the private one
    /// (same-kind lanes only — cached `MacCounts` are policy-specific,
    /// see `EngineBuilder::with_embed_cache`).
    pub fn with_cache(mut self, cache: Arc<EmbedCache>) -> Self {
        self.cache = cache;
        self
    }

    /// The model configuration this engine scores with.
    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// The loaded model weights.
    pub fn weights(&self) -> &Weights {
        &self.weights
    }

    /// The scoring path this engine takes.
    pub fn policy(&self) -> SparsePolicy {
        self.policy
    }

    /// The engine's embedding cache (stats inspection).
    pub fn embed_cache(&self) -> &EmbedCache {
        &self.cache
    }

    /// Score a single encoded pair (no batch packing needed);
    /// cache-aware like every scoring path of this engine.
    pub fn score_pair(&self, g1: &EncodedGraph, g2: &EncodedGraph) -> f32 {
        let (c1, _) = self.embed_cached(g1);
        let (c2, _) = self.embed_cached(g2);
        pair_score(&self.cfg, &self.weights, &c1.hg, &c2.hg).1
    }

    /// Embed one graph through the cache: a hit reuses the stored
    /// post-attention embedding; a miss runs GCN + attention under this
    /// engine's policy and caches the result. Returns the embedding and
    /// whether it was a hit.
    fn embed_cached(&self, g: &EncodedGraph) -> (Arc<CachedEmbed>, bool) {
        match self.cache.get(g.fingerprint()) {
            Some(hit) => (hit, true),
            None => (self.embed_miss(g), false),
        }
    }

    /// The miss half of [`NativeEngine::embed_cached`]: run GCN +
    /// attention and cache the embedding (callers that already probed
    /// the cache use this directly, so hits and misses are each counted
    /// exactly once).
    fn embed_miss(&self, g: &EncodedGraph) -> Arc<CachedEmbed> {
        let emb = embed_graph_with(&self.cfg, &self.weights, g, self.policy);
        let t = &emb.trace;
        let cached = Arc::new(CachedEmbed {
            hg: emb.hg,
            macs: MacCounts {
                macs: t.macs,
                ft_elements: t.ft_elements.iter().sum(),
                agg_elements: t.agg_elements,
            },
        });
        self.cache.insert(g.fingerprint(), Arc::clone(&cached));
        cached
    }

    /// Fold one embed outcome into a query's executed-work + cache
    /// telemetry accumulators.
    fn tally(
        executed: &mut MacCounts,
        stats: &mut EmbedCacheTelemetry,
        c: &CachedEmbed,
        hit: bool,
    ) {
        if hit {
            stats.hits += 1;
        } else {
            stats.misses += 1;
            executed.macs += c.macs.macs;
            executed.ft_elements += c.macs.ft_elements;
            executed.agg_elements += c.macs.agg_elements;
        }
    }

    /// Shared NTN+FCN fan-out of `score_corpus` / `score_corpus_with`:
    /// one score per candidate against a resolved query embedding, each
    /// candidate embedded through the cache, work and cache activity
    /// accumulated into the caller's counters. One code path means the
    /// sharded and unsharded scores cannot diverge.
    fn fan_out_tail(
        &self,
        query_hg: &[f32],
        shard: &[EncodedGraph],
        executed: &mut MacCounts,
        cache_stats: &mut EmbedCacheTelemetry,
    ) -> Vec<f32> {
        let mut scores = Vec::with_capacity(shard.len());
        for g in shard {
            let (c, hit) = self.embed_cached(g);
            Self::tally(executed, cache_stats, &c, hit);
            // Same orientation as the pairwise path: (query, candidate).
            let (_, score) = pair_score(&self.cfg, &self.weights, query_hg, &c.hg);
            scores.push(score);
        }
        scores
    }
}

impl Engine for NativeEngine {
    fn caps(&self) -> &EngineCaps {
        &self.caps
    }

    fn score_batch(&mut self, batch: &PackedBatch) -> Result<BatchOutput, EngineError> {
        let mut scores = Vec::with_capacity(batch.batch);
        let mut telemetry = Vec::with_capacity(batch.batch);
        for i in 0..batch.batch {
            // Probe by the fingerprints packed alongside the tensors
            // (k1/k2): a fully-cached slot skips unpack_slot's
            // O(n_max²) tensor copies entirely — the warm hot path is
            // a mask sanity scan + probe + NTN/FCN tail. Empty padding
            // slots ride the cache like any slot — every pad shares one
            // key — and their well-defined bias-path score is discarded
            // by the caller.
            // Same typed corruption error warm or cold (O(n_max), no
            // copies): cache history must not change error behavior.
            batch.validate_slot_masks(i).map_err(|e| EngineError::InvalidInput {
                detail: format!("slot {i}: {e}"),
            })?;
            let t0 = Instant::now();
            let mut executed = MacCounts::default();
            let mut cache_stats = EmbedCacheTelemetry::default();
            let probe1 = self.cache.get(batch.k1[i]);
            // One key, one probe: a same-graph pair (every padding
            // slot, self-similarity queries) must not count two global
            // misses for the single forward it runs.
            let same = batch.k2[i] == batch.k1[i];
            let probe2 = if same {
                probe1.clone()
            } else {
                self.cache.get(batch.k2[i])
            };
            let (c1, hit1, c2, hit2) = match (probe1, probe2) {
                (Some(c1), Some(c2)) => (c1, true, c2, true),
                (p1, p2) => {
                    // Unpack only the missed side(s): the hit side's
                    // embedding comes from the cache, its tensors are
                    // never read (masks were validated above).
                    let (c1, hit1) = match p1 {
                        Some(c) => (c, true),
                        None => {
                            let g1 = batch.unpack_slot_g1(i).map_err(|e| {
                                EngineError::InvalidInput {
                                    detail: format!("slot {i}: {e}"),
                                }
                            })?;
                            (self.embed_miss(&g1), false)
                        }
                    };
                    let (c2, hit2) = match p2 {
                        Some(c) => (c, true),
                        // Identical graphs in one slot: embedded once
                        // just above, reuse it as a hit.
                        None if same => (Arc::clone(&c1), true),
                        None => {
                            let g2 = batch.unpack_slot_g2(i).map_err(|e| {
                                EngineError::InvalidInput {
                                    detail: format!("slot {i}: {e}"),
                                }
                            })?;
                            (self.embed_miss(&g2), false)
                        }
                    };
                    (c1, hit1, c2, hit2)
                }
            };
            Self::tally(&mut executed, &mut cache_stats, &c1, hit1);
            Self::tally(&mut executed, &mut cache_stats, &c2, hit2);
            let (_, score) = pair_score(&self.cfg, &self.weights, &c1.hg, &c2.hg);
            let cpu_us = t0.elapsed().as_secs_f64() * 1e6;
            cache_stats.entries = self.cache.len() as u64;
            scores.push(score);
            telemetry.push(QueryTelemetry {
                cpu_us: Some(cpu_us),
                macs: Some(executed),
                embed_cache: Some(cache_stats),
                ..QueryTelemetry::default()
            });
        }
        Ok(BatchOutput { scores, telemetry })
    }

    fn score_corpus(
        &mut self,
        query: &EncodedGraph,
        corpus: &[EncodedGraph],
    ) -> Result<CorpusOutput, EngineError> {
        super::check_corpus_shapes(self.cfg.n_max, self.cfg.num_labels, query, corpus)?;
        if corpus.is_empty() {
            // Nothing to rank: no embeds, no work, no skewed telemetry
            // (pipeline admission rejects this; direct API use gets an
            // empty result).
            return Ok(CorpusOutput {
                scores: Vec::new(),
                telemetry: QueryTelemetry::default(),
            });
        }
        let t0 = Instant::now();
        let mut executed = MacCounts::default();
        let mut cache_stats = EmbedCacheTelemetry::default();
        let (cq, hitq) = self.embed_cached(query);
        Self::tally(&mut executed, &mut cache_stats, &cq, hitq);
        let scores = self.fan_out_tail(&cq.hg, corpus, &mut executed, &mut cache_stats);
        cache_stats.entries = self.cache.len() as u64;
        Ok(CorpusOutput {
            scores,
            telemetry: QueryTelemetry {
                cpu_us: Some(t0.elapsed().as_secs_f64() * 1e6),
                macs: Some(executed),
                embed_cache: Some(cache_stats),
                ..QueryTelemetry::default()
            },
        })
    }

    fn embed_query(&mut self, query: &EncodedGraph) -> Result<QueryEmbed, EngineError> {
        super::check_graph_shape(self.cfg.n_max, self.cfg.num_labels, "query graph", query)?;
        let t0 = Instant::now();
        let mut executed = MacCounts::default();
        let mut cache_stats = EmbedCacheTelemetry::default();
        let (cq, hitq) = self.embed_cached(query);
        Self::tally(&mut executed, &mut cache_stats, &cq, hitq);
        cache_stats.entries = self.cache.len() as u64;
        Ok(QueryEmbed {
            embed: cq,
            telemetry: QueryTelemetry {
                cpu_us: Some(t0.elapsed().as_secs_f64() * 1e6),
                macs: Some(executed),
                embed_cache: Some(cache_stats),
                ..QueryTelemetry::default()
            },
        })
    }

    fn score_corpus_with(
        &mut self,
        query_hg: &[f32],
        shard: &[EncodedGraph],
    ) -> Result<CorpusOutput, EngineError> {
        super::check_shard_shapes(self.cfg.n_max, self.cfg.num_labels, "shard", shard)?;
        if query_hg.len() != self.cfg.embed_dim() {
            return Err(EngineError::InvalidInput {
                detail: format!(
                    "query embedding has {} floats, model embeds into {}",
                    query_hg.len(),
                    self.cfg.embed_dim()
                ),
            });
        }
        let t0 = Instant::now();
        let mut executed = MacCounts::default();
        let mut cache_stats = EmbedCacheTelemetry::default();
        let scores = self.fan_out_tail(query_hg, shard, &mut executed, &mut cache_stats);
        cache_stats.entries = self.cache.len() as u64;
        Ok(CorpusOutput {
            scores,
            telemetry: QueryTelemetry {
                cpu_us: Some(t0.elapsed().as_secs_f64() * 1e6),
                macs: Some(executed),
                embed_cache: Some(cache_stats),
                ..QueryTelemetry::default()
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::encode::{encode, PackedBatch};
    use crate::graph::generate::{generate, Family};
    use crate::nn::simgnn::simgnn_score;
    use crate::util::rng::Rng;

    fn tiny() -> NativeEngine {
        let cfg = ModelConfig {
            n_max: 8,
            num_labels: 4,
            filters: [4, 4, 4],
            relu_mask: [true, true, false],
            ntn_k: 4,
            fc_dims: vec![4],
            seed: 0,
        };
        // deterministic pseudo-random weights
        let mut rng = Rng::new(99);
        let mut rand_vec = |len: usize| -> Vec<f32> {
            (0..len).map(|_| (rng.f32() - 0.5) * 0.6).collect()
        };
        let w = Weights {
            gcn_w: [rand_vec(4 * 4), rand_vec(4 * 4), rand_vec(4 * 4)],
            gcn_b: [vec![0.1; 4], vec![0.1; 4], vec![0.1; 4]],
            att_w: rand_vec(16),
            ntn_w: rand_vec(4 * 16),
            ntn_v: rand_vec(4 * 8),
            ntn_b: vec![0.0; 4],
            fc_w: vec![rand_vec(16)],
            fc_b: vec![vec![0.0; 4]],
            out_w: rand_vec(4),
            out_b: vec![0.0],
        };
        // note: gcn_w0 must be (num_labels=4, f1=4): 16 elements — ok.
        NativeEngine::new(cfg, w)
    }

    fn workload(count: usize, seed: u64) -> Vec<(EncodedGraph, EncodedGraph)> {
        let mut rng = Rng::new(seed);
        let f = Family::ErdosRenyi { n: 6, p_millis: 300 };
        (0..count)
            .map(|_| {
                let g1 = generate(&mut rng, f, 8, 4);
                let g2 = generate(&mut rng, f, 8, 4);
                (encode(&g1, 8, 4).unwrap(), encode(&g2, 8, 4).unwrap())
            })
            .collect()
    }

    #[test]
    fn batch_matches_per_pair() {
        let mut eng = tiny();
        let pairs = workload(3, 7);
        let pb = PackedBatch::pack(&pairs, 4).unwrap();
        let out = eng.score_batch(&pb).unwrap();
        assert_eq!(out.scores.len(), 4);
        assert_eq!(out.telemetry.len(), 4);
        for (i, (g1, g2)) in pairs.iter().enumerate() {
            let want = simgnn_score(eng.config(), eng.weights(), g1, g2);
            assert!((out.scores[i] - want).abs() < 1e-6);
        }
        // Per-slot CPU time and MAC counts are reported on every slot.
        assert!(out.telemetry.iter().all(|t| t.cpu_us.is_some()));
        assert!(out.telemetry.iter().all(|t| t.macs.is_some()));
        assert!(out.telemetry.iter().all(|t| t.cycles.is_none() && t.exec.is_none()));
        // Real slots did real work; the padding slot has no nonzeros to
        // process on the sparse path (0-node graphs).
        assert!(out.telemetry[0].macs.unwrap().macs > 0);
        assert_eq!(out.telemetry[3].macs.unwrap().ft_elements, 0);
    }

    #[test]
    fn dense_and_sparse_policies_agree_on_batches() {
        // Engine-level dense↔sparse parity across every ladder size,
        // padded tail slots included (the acceptance bar is 1e-5; the
        // paths are in fact bit-identical by construction).
        let mut sparse = tiny();
        let mut dense = NativeEngine::new(sparse.cfg.clone(), sparse.weights.clone())
            .with_policy(SparsePolicy::Dense);
        assert_eq!(sparse.policy(), SparsePolicy::Csr);
        let ladder = sparse.caps().batch_ladder().to_vec();
        for (bi, &b) in ladder.iter().enumerate() {
            // Underfill by one where possible so tail padding is covered.
            let fill = if b > 1 { b - 1 } else { 1 };
            let pairs = workload(fill, 100 + bi as u64);
            let pb = PackedBatch::pack(&pairs, b).unwrap();
            let s = sparse.score_batch(&pb).unwrap();
            let d = dense.score_batch(&pb).unwrap();
            for (i, (ss, ds)) in s.scores.iter().zip(d.scores.iter()).enumerate() {
                assert!(
                    (ss - ds).abs() < 1e-5,
                    "batch {b} slot {i}: sparse {ss} vs dense {ds}"
                );
            }
            // The sparse path reports strictly less counted work.
            let sm = s.telemetry[0].macs.unwrap();
            let dm = d.telemetry[0].macs.unwrap();
            assert!(sm.macs < dm.macs, "sparse {sm:?} !< dense {dm:?}");
            assert!(sm.ft_elements < dm.ft_elements);
            assert!(sm.agg_elements < dm.agg_elements);
        }
    }

    #[test]
    fn caps_describe_the_cpu_profile() {
        let eng = tiny();
        let caps = eng.caps();
        assert_eq!(caps.name, "native-cpu");
        assert_eq!(caps.batch_ladder(), &AOT_BATCH_LADDER);
        assert_eq!(caps.max_nodes, 8);
        assert_eq!(caps.max_labels, 4);
        assert!(!caps.reports_cycles);
        assert!(!caps.reports_exec_timing);
        assert!(caps.reports_macs);
        assert!(caps.reports_embed_cache);
        assert!(caps.supports_corpus);
        assert!(caps.supports_corpus_shards);
        // The dense comparison lane is named apart.
        let dense = tiny().with_policy(SparsePolicy::Dense);
        assert_eq!(dense.caps().name, "native-cpu-dense");
    }

    #[test]
    fn sharded_corpus_path_matches_score_corpus_bitwise() {
        // Two engines sharing one cache stand in for two executor
        // lanes: lane A embeds the query once (embed_query), both lanes
        // score disjoint shards against the shipped embedding, and the
        // concatenated scores must be bit-identical to one unsharded
        // score_corpus on a fresh engine.
        let base = tiny();
        let shared = Arc::new(EmbedCache::new(512));
        let mut lane_a = NativeEngine::new(base.cfg.clone(), base.weights.clone())
            .with_cache(Arc::clone(&shared));
        let mut lane_b = NativeEngine::new(base.cfg.clone(), base.weights.clone())
            .with_cache(Arc::clone(&shared));
        let corpus: Vec<EncodedGraph> = workload(4, 51)
            .into_iter()
            .flat_map(|(a, b)| [a, b])
            .collect(); // 8 candidates
        let (query, _) = workload(1, 52).pop().unwrap();

        let mut reference = tiny();
        let want = reference.score_corpus(&query, &corpus).unwrap().scores;

        let embed = lane_a.embed_query(&query).unwrap();
        assert_eq!(embed.telemetry.embed_cache.unwrap().misses, 1, "cold query embeds once");
        let first = lane_a.score_corpus_with(&embed.embed.hg, &corpus[..5]).unwrap();
        let second = lane_b.score_corpus_with(&embed.embed.hg, &corpus[5..]).unwrap();
        let mut got = first.scores.clone();
        got.extend_from_slice(&second.scores);
        assert_eq!(got, want, "sharded scores diverged from score_corpus");
        // The shared cache kept the total at one forward per unique
        // graph across both lanes (the corpus graphs are random, so
        // derive the expected counts from the fingerprints).
        let mut uniq: std::collections::HashSet<u128> =
            corpus.iter().map(|g| g.fingerprint().0).collect();
        let a = first.telemetry.embed_cache.unwrap();
        let b = second.telemetry.embed_cache.unwrap();
        let candidate_misses = uniq.iter().filter(|&&k| k != query.fingerprint().0).count();
        assert_eq!(
            a.misses + b.misses,
            candidate_misses as u64,
            "each unique candidate embeds exactly once across the lanes"
        );
        uniq.insert(query.fingerprint().0);
        assert_eq!(shared.stats().entries as usize, uniq.len());
        // A repeated shard on the *other* lane is all hits — the
        // warming crossed lanes.
        let again = lane_b.score_corpus_with(&embed.embed.hg, &corpus[..5]).unwrap();
        assert_eq!(again.scores, first.scores);
        assert_eq!(again.telemetry.embed_cache.unwrap().misses, 0);
    }

    #[test]
    fn score_corpus_with_rejects_bad_inputs() {
        let mut eng = tiny();
        let (query, other) = workload(1, 53).pop().unwrap();
        let embed = eng.embed_query(&query).unwrap();
        // Wrong embedding width: typed error, not garbage scores.
        let err = eng
            .score_corpus_with(&embed.embed.hg[..2], std::slice::from_ref(&other))
            .unwrap_err();
        assert!(matches!(err, EngineError::InvalidInput { .. }));
        // Mis-shaped shard entry: same typed error as score_corpus.
        let wide = {
            let g = generate(&mut Rng::new(54), Family::ErdosRenyi { n: 5, p_millis: 300 }, 8, 4);
            crate::graph::encode::encode(&g, 16, 4).unwrap()
        };
        let err = eng
            .score_corpus_with(&embed.embed.hg, std::slice::from_ref(&wide))
            .unwrap_err();
        // Shard-local labeling: the engine only sees its slice, so the
        // error must not claim a position in the full corpus.
        assert!(
            matches!(err, EngineError::InvalidInput { ref detail } if detail.contains("shard[0]"))
        );
        // Mis-shaped query graph at embed time.
        assert!(matches!(
            eng.embed_query(&wide),
            Err(EngineError::InvalidInput { .. })
        ));
        // An empty shard is a valid (empty) result.
        assert!(eng.score_corpus_with(&embed.embed.hg, &[]).unwrap().scores.is_empty());
    }

    #[test]
    fn cache_dedups_within_batch_and_across_queries() {
        let mut eng = tiny();
        let pairs = workload(2, 21);
        // Batch layout: (a,b), (a,b), (b,a) — five of six embeds repeat.
        let (a, b) = pairs[0].clone();
        let repeated = vec![(a.clone(), b.clone()), (a.clone(), b.clone()), (b, a)];
        let pb = PackedBatch::pack(&repeated, 4).unwrap();
        let out = eng.score_batch(&pb).unwrap();
        // Slot 0: cold — two misses, real work.
        let s0 = out.telemetry[0].embed_cache.unwrap();
        assert_eq!((s0.hits, s0.misses), (0, 2));
        assert!(out.telemetry[0].macs.unwrap().macs > 0);
        // Slots 1 and 2: all hits, zero GCN work executed.
        for i in [1, 2] {
            let s = out.telemetry[i].embed_cache.unwrap();
            assert_eq!((s.hits, s.misses), (2, 0), "slot {i}");
            assert_eq!(out.telemetry[i].macs.unwrap(), MacCounts::default(), "slot {i}");
        }
        // Identical scores for identical pairs, bit for bit.
        assert_eq!(out.scores[0], out.scores[1]);
        // Across queries: rescoring the same batch is now all hits and
        // still returns bit-identical scores.
        let again = eng.score_batch(&pb).unwrap();
        assert_eq!(out.scores, again.scores);
        for t in &again.telemetry {
            assert_eq!(t.embed_cache.unwrap().misses, 0);
        }
        let stats = eng.embed_cache().stats();
        assert!(stats.hits > 0 && stats.misses > 0);
        assert_eq!(stats.entries as usize, eng.embed_cache().len());
    }

    #[test]
    fn corrupted_mask_errors_warm_or_cold() {
        // The warm fast path skips unpack but not mask validation:
        // cache history must not flip a corrupted batch from a typed
        // error into silently served scores.
        let mut eng = tiny();
        let pairs = workload(1, 61);
        let mut pb = PackedBatch::pack(&pairs, 1).unwrap();
        eng.score_batch(&pb).unwrap(); // warm the cache
        pb.m1[1] = 0.0; // interior zero: non-prefix mask
        assert!(matches!(
            eng.score_batch(&pb),
            Err(EngineError::InvalidInput { .. })
        ));
    }

    #[test]
    fn score_corpus_rejects_mismatched_encode_shapes() {
        // Direct API misuse (no pipeline admission in front): a corpus
        // encoded for other artifact shapes must come back as a typed
        // error, not an index panic or silently wrong scores.
        let mut eng = tiny(); // expects (n_max, labels) = (8, 4)
        let g = generate(&mut Rng::new(44), Family::ErdosRenyi { n: 5, p_millis: 300 }, 8, 4);
        let ok = encode(&g, 8, 4).unwrap();
        let wide = encode(&g, 16, 4).unwrap();
        let err = eng.score_corpus(&wide, std::slice::from_ref(&ok)).unwrap_err();
        assert!(
            matches!(err, EngineError::InvalidInput { ref detail } if detail.contains("query")),
            "{err}"
        );
        let err = eng
            .score_corpus(&ok, &[ok.clone(), wide.clone()])
            .unwrap_err();
        assert!(
            matches!(err, EngineError::InvalidInput { ref detail } if detail.contains("corpus[1]")),
            "{err}"
        );
        // Matching shapes still score.
        assert!(eng.score_corpus(&ok, std::slice::from_ref(&ok)).is_ok());
    }

    #[test]
    fn corpus_scoring_matches_pairwise_and_counts_unique_forwards() {
        let mut eng = tiny();
        // 6 corpus entries, 4 unique graphs (two duplicated), plus one
        // distinct query graph -> exactly 5 GCN forwards expected.
        let uniques: Vec<EncodedGraph> = workload(2, 31)
            .into_iter()
            .flat_map(|(a, b)| [a, b])
            .collect();
        let corpus = vec![
            uniques[0].clone(),
            uniques[1].clone(),
            uniques[2].clone(),
            uniques[3].clone(),
            uniques[0].clone(),
            uniques[2].clone(),
        ];
        let (query, _) = workload(1, 32).pop().unwrap();
        let out = eng.score_corpus(&query, &corpus).unwrap();
        assert_eq!(out.scores.len(), 6);
        let cs = out.telemetry.embed_cache.unwrap();
        assert_eq!(cs.misses, 5, "one forward per unique graph (query + 4)");
        assert_eq!(cs.hits, 2, "duplicated corpus entries hit");
        assert_eq!(cs.entries, 5);
        // Bit-identical to the pairwise path on a fresh engine.
        let mut fresh = tiny();
        let pairs: Vec<_> = corpus.iter().map(|c| (query.clone(), c.clone())).collect();
        let pb = PackedBatch::pack(&pairs, pairs.len()).unwrap();
        let pairwise = fresh.score_batch(&pb).unwrap();
        assert_eq!(out.scores, &pairwise.scores[..6]);
        // A repeat query is served entirely from the cache.
        let warm = eng.score_corpus(&query, &corpus).unwrap();
        assert_eq!(warm.scores, out.scores);
        let ws = warm.telemetry.embed_cache.unwrap();
        assert_eq!((ws.hits, ws.misses), (7, 0));
        assert_eq!(warm.telemetry.macs.unwrap(), MacCounts::default());
    }

    #[test]
    fn ladder_follows_meta_manifest() {
        // Both engines' ladders flow from one meta source: a manifest
        // with a custom artifact ladder yields caps advertising exactly
        // that ladder (the PJRT engine compiles one executable per entry
        // of the same list), and the meta-less default is the shared
        // AOT_BATCH_LADDER constant.
        let eng = tiny();
        let custom = NativeEngine::from_parts(
            eng.cfg.clone(),
            eng.weights.clone(),
            vec![1, 8],
        );
        assert_eq!(custom.caps().batch_ladder(), &[1, 8]);
        let meta_doc = crate::util::json::parse(
            r#"{"config": {"filters": [4, 4, 4],
                "relu_mask": [true, true, false], "n_max": 8,
                "num_labels": 4, "ntn_k": 4, "fc_dims": [4]}}"#,
        )
        .unwrap();
        let meta = ArtifactsMeta::from_json(&meta_doc).unwrap();
        let from_meta = NativeEngine::from_parts(meta.config, eng.weights.clone(), meta.batch_sizes);
        assert_eq!(from_meta.caps().batch_ladder(), &AOT_BATCH_LADDER);
        assert_eq!(eng.caps().batch_ladder(), &AOT_BATCH_LADDER);
    }
}
