//! Execution engines for scoring graph-pair batches.
//!
//! The coordinator (L3) is engine-agnostic: it batches queries into
//! `PackedBatch`es and hands them to an `Engine`. Three engines exist:
//!
//!  * [`pjrt::XlaEngine`] — the production path: loads the AOT-compiled
//!    HLO text artifacts (L2 jax model + L1 Pallas kernels) and executes
//!    them on the PJRT CPU client. Python is never involved.
//!  * [`native::NativeEngine`] — the independent rust reference numerics;
//!    doubles as the "PyG-CPU"-style measured baseline.
//!  * `sim::engine::SimEngine` — functional result + FPGA cycle report
//!    from the SPA-GCN cycle simulator (defined in the sim module).

pub mod native;
pub mod pjrt;

use crate::graph::encode::PackedBatch;

/// Thread-safe constructor for engines; workers call it in-thread.
pub type EngineFactory = std::sync::Arc<dyn Fn() -> anyhow::Result<Box<dyn Engine>> + Send + Sync>;

/// A batch-scoring backend.
///
/// Note: deliberately NOT `Send` — the xla crate's PJRT handles are
/// `Rc`-based. Worker threads construct their own engine via an
/// `EngineFactory` (which IS Send) inside the thread.
pub trait Engine {
    /// Human-readable engine name for logs/metrics.
    fn name(&self) -> &str;

    /// Batch sizes this engine can execute directly. The batcher selects
    /// from these; `score_batch` must receive one of them.
    fn supported_batch_sizes(&self) -> Vec<usize>;

    /// Score `batch.batch` pairs; returns one similarity per slot
    /// (padding slots included — caller truncates).
    fn score_batch(&mut self, batch: &PackedBatch) -> anyhow::Result<Vec<f32>>;
}

/// Pick the smallest supported batch size >= `pending`, or the largest
/// available if `pending` exceeds them all (the caller then loops).
pub fn pick_batch_size(supported: &[usize], pending: usize) -> usize {
    let mut sizes = supported.to_vec();
    sizes.sort_unstable();
    for &s in &sizes {
        if s >= pending {
            return s;
        }
    }
    *sizes.last().expect("engine supports no batch sizes")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_batch_rounds_up() {
        let sizes = vec![1, 4, 16, 64];
        assert_eq!(pick_batch_size(&sizes, 1), 1);
        assert_eq!(pick_batch_size(&sizes, 3), 4);
        assert_eq!(pick_batch_size(&sizes, 16), 16);
        assert_eq!(pick_batch_size(&sizes, 17), 64);
        assert_eq!(pick_batch_size(&sizes, 1000), 64);
    }
}
