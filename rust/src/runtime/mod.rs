//! Execution engines for scoring graph-pair batches (the Engine API v2).
//!
//! The coordinator (L3) is engine-agnostic: it batches queries into
//! `PackedBatch`es and hands them to an [`Engine`]. Three backends exist,
//! identified by [`EngineKind`] and constructed through [`EngineBuilder`]:
//!
//!  * [`pjrt::XlaEngine`] — the production path: loads the AOT-compiled
//!    HLO text artifacts (L2 jax model + L1 Pallas kernels) and executes
//!    them on the PJRT CPU client. Python is never involved.
//!  * [`native::NativeEngine`] — the independent rust reference numerics;
//!    doubles as the "PyG-CPU"-style measured baseline.
//!  * `sim::engine::SimEngine` — functional result + FPGA cycle report
//!    from the SPA-GCN cycle simulator (defined in the sim module).
//!
//! Engines *declare* what they can do through [`EngineCaps`] (batch
//! ladder, shape limits, which telemetry they report) instead of being
//! string-matched, and every [`Engine::score_batch`] call returns a
//! [`BatchOutput`] carrying per-slot [`QueryTelemetry`] — cycle reports
//! from the simulator, DMA/execute timing from PJRT, per-slot CPU time
//! from the native path — so the serving report can surface the paper's
//! cycle-level numbers (Table 4/5/6, Fig. 11) instead of discarding them.

pub mod embed_cache;
pub mod native;
pub mod pjrt;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::graph::encode::{EncodedGraph, PackedBatch};

/// The set of engine backends, replacing `&str` dispatch. Parse with
/// [`std::str::FromStr`]
/// (`"xla" | "xla-fused" | "native" | "native-dense" | "sim"`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EngineKind {
    /// PJRT-executed AOT artifacts (Pallas-kernel flavor) — production.
    Xla,
    /// PJRT-executed fused (pure-jnp) artifact flavor: identical math,
    /// faster on the CPU PJRT backend (EXPERIMENTS.md §Perf L2).
    XlaFused,
    /// Independent rust reference numerics on the sparse scoring path
    /// (CSR aggregation + one-hot FT); the measured CPU baseline.
    Native,
    /// The same numerics forced onto the dense padded path — the
    /// comparison lane for the dense-vs-sparse serving experiment.
    NativeDense,
    /// Functional scores + SPA-GCN cycle simulation.
    Sim,
}

impl EngineKind {
    /// Every valid kind, in CLI help order.
    pub const ALL: [EngineKind; 5] = [
        EngineKind::Xla,
        EngineKind::XlaFused,
        EngineKind::Native,
        EngineKind::NativeDense,
        EngineKind::Sim,
    ];

    /// The stable CLI spelling of this kind.
    pub fn as_str(&self) -> &'static str {
        match self {
            EngineKind::Xla => "xla",
            EngineKind::XlaFused => "xla-fused",
            EngineKind::Native => "native",
            EngineKind::NativeDense => "native-dense",
            EngineKind::Sim => "sim",
        }
    }

    /// Parse a comma-separated kind list (`"native,sim"`); empty
    /// segments (trailing commas, stray spaces) are ignored, but the
    /// list as a whole must name at least one kind. Shared by the CLI
    /// and the examples so the accepted syntax cannot drift.
    pub fn parse_list(spec: &str) -> Result<Vec<EngineKind>, EngineError> {
        let kinds = spec
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::parse)
            .collect::<Result<Vec<EngineKind>, EngineError>>()?;
        if kinds.is_empty() {
            return Err(EngineError::UnknownKind(spec.to_string()));
        }
        Ok(kinds)
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for EngineKind {
    type Err = EngineError;

    fn from_str(s: &str) -> Result<Self, EngineError> {
        EngineKind::ALL
            .into_iter()
            .find(|k| k.as_str() == s)
            .ok_or_else(|| EngineError::UnknownKind(s.to_string()))
    }
}

/// Static capability descriptor an engine publishes at construction.
///
/// The batch ladder is sorted (and deduplicated) once here, so batch-size
/// selection never re-sorts on the hot path, and the telemetry flags tell
/// the coordinator which [`QueryTelemetry`] fields this engine fills.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EngineCaps {
    /// Human-readable engine name for logs/metrics (e.g. `"xla-pjrt"`).
    pub name: String,
    /// Batch sizes the engine can execute directly, ascending, non-empty.
    ladder: Vec<usize>,
    /// Largest graph (node count) the engine accepts.
    pub max_nodes: usize,
    /// Label vocabulary size the engine was built for.
    pub max_labels: usize,
    /// Fills [`QueryTelemetry::cycles`] (the cycle simulator).
    pub reports_cycles: bool,
    /// Fills [`QueryTelemetry::exec`] (device upload/execute/download).
    pub reports_exec_timing: bool,
    /// Fills [`QueryTelemetry::macs`] (MAC/nonzero work counts).
    pub reports_macs: bool,
    /// Fills [`QueryTelemetry::embed_cache`] (graph-embedding cache
    /// hit/miss activity — DESIGN.md S14).
    pub reports_embed_cache: bool,
    /// Implements [`Engine::score_corpus`] (one-vs-many top-k search).
    pub supports_corpus: bool,
    /// Implements the scatter/gather pair [`Engine::embed_query`] +
    /// [`Engine::score_corpus_with`]: a corpus query may be split into
    /// shards across lanes of this engine (DESIGN.md S15).
    pub supports_corpus_shards: bool,
}

impl EngineCaps {
    /// Build a descriptor; `ladder` is sorted and deduplicated here and
    /// must be non-empty. Telemetry flags default to off — see
    /// [`EngineCaps::with_cycle_reports`] / [`EngineCaps::with_exec_timing`].
    pub fn new(
        name: impl Into<String>,
        mut ladder: Vec<usize>,
        max_nodes: usize,
        max_labels: usize,
    ) -> Self {
        ladder.sort_unstable();
        ladder.dedup();
        assert!(!ladder.is_empty(), "engine must support at least one batch size");
        EngineCaps {
            name: name.into(),
            ladder,
            max_nodes,
            max_labels,
            reports_cycles: false,
            reports_exec_timing: false,
            reports_macs: false,
            reports_embed_cache: false,
            supports_corpus: false,
            supports_corpus_shards: false,
        }
    }

    /// Mark the engine as filling [`QueryTelemetry::cycles`].
    pub fn with_cycle_reports(mut self) -> Self {
        self.reports_cycles = true;
        self
    }

    /// Mark the engine as filling [`QueryTelemetry::exec`].
    pub fn with_exec_timing(mut self) -> Self {
        self.reports_exec_timing = true;
        self
    }

    /// Mark the engine as filling [`QueryTelemetry::macs`].
    pub fn with_mac_counts(mut self) -> Self {
        self.reports_macs = true;
        self
    }

    /// Mark the engine as filling [`QueryTelemetry::embed_cache`].
    pub fn with_embed_cache(mut self) -> Self {
        self.reports_embed_cache = true;
        self
    }

    /// Mark the engine as implementing [`Engine::score_corpus`].
    pub fn with_corpus_scoring(mut self) -> Self {
        self.supports_corpus = true;
        self
    }

    /// Mark the engine as implementing [`Engine::embed_query`] +
    /// [`Engine::score_corpus_with`] (sharded corpus scoring).
    pub fn with_corpus_sharding(mut self) -> Self {
        self.supports_corpus_shards = true;
        self
    }

    /// The supported batch sizes, ascending.
    pub fn batch_ladder(&self) -> &[usize] {
        &self.ladder
    }

    /// The largest supported batch size.
    pub fn max_batch(&self) -> usize {
        *self.ladder.last().expect("ladder is non-empty by construction")
    }

    /// Pick the smallest supported batch size >= `pending`, or the
    /// largest available if `pending` exceeds them all (the caller then
    /// loops). No allocation, no re-sort: the ladder is sorted once at
    /// construction.
    pub fn pick_batch_size(&self, pending: usize) -> usize {
        for &s in &self.ladder {
            if s >= pending {
                return s;
            }
        }
        self.max_batch()
    }
}

/// Cycle-level result of simulating one query (the serving-path subset
/// of the simulator's per-query report).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CycleReport {
    /// Steady-state interval between query completions, cycles.
    pub interval: u64,
    /// One-query latency, cycles.
    pub latency: u64,
}

/// Timing breakdown of one device execute call (for Fig. 11-style
/// analyses). All values are per-chunk, µs.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ExecTiming {
    /// Host-side input literal construction ("DMA write" analogue), µs.
    pub upload_us: f64,
    /// Device execute, µs.
    pub execute_us: f64,
    /// Output literal -> host vec ("DMA read" analogue), µs.
    pub download_us: f64,
}

/// MAC/nonzero work counts for one scored slot (both graphs of the pair,
/// GCN stage): the software analogue of the paper's Table 6 sparsity
/// savings. The sparse path counts the real nonzero work it executed;
/// the dense path counts the full padded *schedule* — what a dense
/// datapath (the paper's baseline hardware) would execute for those
/// shapes. The dense/sparse ratio in the serve report is therefore the
/// Table 6-style schedule saving; it deliberately overstates the CPU
/// wall-clock gain, because the dense reference loop itself skips zero
/// activations at runtime.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MacCounts {
    /// Multiply-accumulates executed across FT + aggregation.
    pub macs: u64,
    /// Input elements the feature transform consumed.
    pub ft_elements: u64,
    /// Adjacency entries the aggregation consumed.
    pub agg_elements: u64,
}

/// Graph-embedding cache activity for one scored query
/// (`reports_embed_cache`). A pair query touches two graphs; a corpus
/// query touches `1 + corpus.len()`. `misses` is exactly the number of
/// GCN+attention forwards the query executed — the acceptance metric
/// for the one-vs-many path (a corpus query must run `unique_graphs`
/// forwards, never `1 + K`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EmbedCacheTelemetry {
    /// Graph embeddings reused from the cache.
    pub hits: u64,
    /// Graph embeddings computed (GCN + attention forwards executed).
    pub misses: u64,
    /// Cache entry count right after this query.
    pub entries: u64,
}

impl EmbedCacheTelemetry {
    /// GCN forwards this query executed (alias for `misses`).
    pub fn gcn_forwards(&self) -> u64 {
        self.misses
    }
}

/// Per-slot telemetry attached to a [`BatchOutput`]. Which fields are
/// filled is declared by the engine's [`EngineCaps`] flags; padding slots
/// carry an empty default.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct QueryTelemetry {
    /// FPGA cycle report from the cycle simulator (`reports_cycles`).
    pub cycles: Option<CycleReport>,
    /// Upload/execute/download split of the chunk this slot rode in
    /// (`reports_exec_timing`; shared by every slot of the chunk).
    pub exec: Option<ExecTiming>,
    /// CPU time spent scoring this slot, µs (native engine).
    pub cpu_us: Option<f64>,
    /// MAC/nonzero work counts for this slot (`reports_macs`). With an
    /// embedding cache active this counts the work *executed*: cached
    /// graphs contribute zero, so the rows show the saving.
    pub macs: Option<MacCounts>,
    /// Embedding-cache hit/miss activity (`reports_embed_cache`).
    pub embed_cache: Option<EmbedCacheTelemetry>,
}

impl QueryTelemetry {
    /// Fold `other` into `self` as work that ran *after* `self` on the
    /// same lane (the embedder lane's query embed followed by its shard
    /// fan-out): every counter sums, cycle reports sum component-wise,
    /// the cache-entries gauge keeps the max.
    pub fn merge_serial(&mut self, other: &QueryTelemetry) {
        self.cycles = merge_opt(self.cycles, other.cycles, |a, b| CycleReport {
            interval: a.interval + b.interval,
            latency: a.latency + b.latency,
        });
        self.exec = merge_opt(self.exec, other.exec, |a, b| ExecTiming {
            upload_us: a.upload_us + b.upload_us,
            execute_us: a.execute_us + b.execute_us,
            download_us: a.download_us + b.download_us,
        });
        self.cpu_us = merge_opt(self.cpu_us, other.cpu_us, |a, b| a + b);
        self.macs = merge_opt(self.macs, other.macs, merge_macs);
        self.embed_cache = merge_opt(self.embed_cache, other.embed_cache, merge_cache);
    }

    /// Fold `other` into `self` as work that ran *concurrently* on a
    /// sibling lane (gather-stage shard merge): work counters (MACs,
    /// CPU time, cache activity) still sum — they are total work — but
    /// cycle reports take the component-wise max, because parallel
    /// shards overlap on independent modeled accelerators. This is how
    /// the cycle model shows the scatter's speedup: the merged query
    /// charges the slowest shard, not the sum of all shards.
    pub fn merge_parallel(&mut self, other: &QueryTelemetry) {
        self.cycles = merge_opt(self.cycles, other.cycles, |a, b| CycleReport {
            interval: a.interval.max(b.interval),
            latency: a.latency.max(b.latency),
        });
        self.exec = merge_opt(self.exec, other.exec, |a, b| ExecTiming {
            upload_us: a.upload_us.max(b.upload_us),
            execute_us: a.execute_us.max(b.execute_us),
            download_us: a.download_us.max(b.download_us),
        });
        self.cpu_us = merge_opt(self.cpu_us, other.cpu_us, |a, b| a + b);
        self.macs = merge_opt(self.macs, other.macs, merge_macs);
        self.embed_cache = merge_opt(self.embed_cache, other.embed_cache, merge_cache);
    }
}

/// Combine two optional telemetry fields: one side absent keeps the
/// other, both present combine via `f`.
fn merge_opt<T: Copy>(a: Option<T>, b: Option<T>, f: impl FnOnce(T, T) -> T) -> Option<T> {
    match (a, b) {
        (Some(a), Some(b)) => Some(f(a, b)),
        (a, b) => a.or(b),
    }
}

fn merge_macs(a: MacCounts, b: MacCounts) -> MacCounts {
    MacCounts {
        macs: a.macs + b.macs,
        ft_elements: a.ft_elements + b.ft_elements,
        agg_elements: a.agg_elements + b.agg_elements,
    }
}

fn merge_cache(a: EmbedCacheTelemetry, b: EmbedCacheTelemetry) -> EmbedCacheTelemetry {
    EmbedCacheTelemetry {
        hits: a.hits + b.hits,
        misses: a.misses + b.misses,
        // A gauge, not a counter: the biggest cache state observed.
        entries: a.entries.max(b.entries),
    }
}

/// What one [`Engine::score_batch`] call returns: one similarity score
/// per slot (padding slots included — the caller truncates) plus one
/// [`QueryTelemetry`] per slot.
#[derive(Debug, Clone)]
pub struct BatchOutput {
    /// One similarity per slot, `len == batch.batch`.
    pub scores: Vec<f32>,
    /// One telemetry record per slot, `len == scores.len()`.
    pub telemetry: Vec<QueryTelemetry>,
}

impl BatchOutput {
    /// Output with `scores` and default (empty) telemetry per slot.
    pub fn untimed(scores: Vec<f32>) -> Self {
        let telemetry = vec![QueryTelemetry::default(); scores.len()];
        BatchOutput { scores, telemetry }
    }
}

/// What one [`Engine::embed_query`] call returns: the cached embedding
/// of a scattered corpus query's graph — computed once at scatter time
/// and shipped to every sibling lane's shard job — plus the telemetry
/// of producing it (one cache probe; a miss is one GCN forward).
#[derive(Debug, Clone)]
pub struct QueryEmbed {
    /// The post-attention embedding (plus the work that produced it),
    /// behind `Arc` so shipping it across lanes is a pointer clone.
    pub embed: Arc<embed_cache::CachedEmbed>,
    /// Cost of this embed: cache probe, executed work, cycles.
    pub telemetry: QueryTelemetry,
}

/// What one [`Engine::score_corpus`] call returns: one similarity per
/// corpus entry (same order as the input slice) plus one telemetry
/// record covering the whole one-vs-many query. Ranking/top-k selection
/// is the caller's job — the engine does not know corpus ids.
#[derive(Debug, Clone)]
pub struct CorpusOutput {
    /// `scores[i]` = similarity(query, corpus[i]); `len == corpus.len()`.
    pub scores: Vec<f32>,
    /// Aggregate telemetry for the query (cache hits across the fan-out,
    /// executed MAC counts, cycles).
    pub telemetry: QueryTelemetry,
}

/// Typed errors at the engine trait boundary (replaces `anyhow` and the
/// stringly `Outcome::EngineError(String)`).
#[derive(Debug, Clone, PartialEq)]
pub enum EngineError {
    /// A CLI/config engine name that is not an [`EngineKind`].
    UnknownKind(String),
    /// The engine could not be constructed or its lane has shut down.
    Unavailable {
        /// What failed (construction error, dead stage, ...).
        reason: String,
    },
    /// `score_batch` was handed a batch size outside the ladder.
    UnsupportedBatch {
        /// The offending packed batch size.
        batch: usize,
        /// The ladder the engine advertises.
        ladder: Vec<usize>,
    },
    /// A query that cannot be encoded for the engine's fixed shapes.
    InvalidInput {
        /// Human-readable encode failure.
        detail: String,
    },
    /// The underlying backend (PJRT, simulator, ...) failed.
    Backend {
        /// Engine name from its caps.
        engine: String,
        /// Backend error rendered to text.
        detail: String,
    },
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::UnknownKind(s) => {
                let valid: Vec<&str> = EngineKind::ALL.iter().map(|k| k.as_str()).collect();
                write!(f, "unknown engine '{s}' (expected one of {})", valid.join("|"))
            }
            EngineError::Unavailable { reason } => write!(f, "engine unavailable: {reason}"),
            EngineError::UnsupportedBatch { batch, ladder } => {
                write!(f, "no artifact for batch size {batch} (ladder {ladder:?})")
            }
            EngineError::InvalidInput { detail } => write!(f, "invalid input: {detail}"),
            EngineError::Backend { engine, detail } => write!(f, "{engine} backend: {detail}"),
        }
    }
}

impl std::error::Error for EngineError {}

/// Validate that `query` and every corpus entry were encoded for the
/// engine's artifact shapes `(n_max, num_labels)`. Engines call this at
/// the top of [`Engine::score_corpus`]: the pipeline's admission
/// rejects mismatched corpora before they get here, but direct API
/// users (examples, tests) deserve the same protection as a typed
/// error instead of an index panic or silently wrong scores from
/// mis-strided tensor reads. O(1) per graph, no allocation on success.
pub(crate) fn check_corpus_shapes(
    n_max: usize,
    num_labels: usize,
    query: &EncodedGraph,
    corpus: &[EncodedGraph],
) -> Result<(), EngineError> {
    check_graph_shape(n_max, num_labels, "query graph", query)?;
    check_shard_shapes(n_max, num_labels, "corpus", corpus)
}

/// The candidate half of [`check_corpus_shapes`]. `what` labels the
/// slice in errors: whole-corpus callers pass `"corpus"`, shard jobs
/// pass `"shard"` — a shard only knows its *local* indices, so calling
/// a bad candidate `corpus[i]` would point operators at the wrong
/// entry of the full corpus.
pub(crate) fn check_shard_shapes(
    n_max: usize,
    num_labels: usize,
    what: &str,
    corpus: &[EncodedGraph],
) -> Result<(), EngineError> {
    for (i, g) in corpus.iter().enumerate() {
        check_graph_shape(n_max, num_labels, &format!("{what}[{i}]"), g)?;
    }
    Ok(())
}

pub(crate) fn check_graph_shape(
    n_max: usize,
    num_labels: usize,
    what: &str,
    g: &EncodedGraph,
) -> Result<(), EngineError> {
    let n = g.mask.len();
    let got = (n, if n == 0 { 0 } else { g.h0.len() / n });
    if got != (n_max, num_labels) {
        return Err(EngineError::InvalidInput {
            detail: format!(
                "{what} encoded for (n_max, labels) = {got:?}, \
                 engine expects ({n_max}, {num_labels})"
            ),
        });
    }
    Ok(())
}

/// Thread-safe constructor for engines; workers call it in-thread.
pub type EngineFactory =
    Arc<dyn Fn() -> Result<Box<dyn Engine>, EngineError> + Send + Sync>;

/// A batch-scoring backend (Engine API v2).
///
/// Note: deliberately NOT `Send` — the xla crate's PJRT handles are
/// `Rc`-based. Worker threads construct their own engine via an
/// [`EngineFactory`] (which IS `Send`) inside the thread.
pub trait Engine {
    /// The engine's static capabilities: name, batch ladder, shape
    /// limits, and which telemetry fields it reports.
    fn caps(&self) -> &EngineCaps;

    /// Score `batch.batch` pairs. `batch.batch` must be on the caps
    /// ladder; the scores vector covers every slot (padding included —
    /// the caller truncates) and telemetry is per-slot.
    fn score_batch(&mut self, batch: &PackedBatch) -> Result<BatchOutput, EngineError>;

    /// One-vs-many scoring: embed `query` once (through the engine's
    /// embedding cache where it has one) and fan the NTN+FCN tail out
    /// over `corpus`, returning one score per entry. Scores must be
    /// bit-identical to scoring each `(query, corpus[i])` pair through
    /// [`Engine::score_batch`]. Engines without an embedding cache
    /// (`caps().supports_corpus == false`) keep this default, which
    /// reports a typed error instead of silently falling back to K full
    /// pairwise forwards.
    fn score_corpus(
        &mut self,
        query: &EncodedGraph,
        corpus: &[EncodedGraph],
    ) -> Result<CorpusOutput, EngineError> {
        let _ = (query, corpus);
        Err(EngineError::Unavailable {
            reason: format!("{} does not support corpus scoring", self.caps().name),
        })
    }

    /// Scatter-time half of a sharded corpus query: embed `query` once
    /// (through the engine's embedding cache where it has one) and
    /// return the embedding for shipment to sibling lanes' shard jobs —
    /// this is what keeps a scattered query at one GCN forward for the
    /// query graph instead of one per lane. Engines without the
    /// `supports_corpus_shards` cap keep this default, a typed error.
    fn embed_query(&mut self, query: &EncodedGraph) -> Result<QueryEmbed, EngineError> {
        let _ = query;
        Err(EngineError::Unavailable {
            reason: format!("{} does not support sharded corpus scoring", self.caps().name),
        })
    }

    /// Shard-side half of a sharded corpus query: fan the NTN+FCN tail
    /// of a *precomputed* query embedding (`query_hg`, from
    /// [`Engine::embed_query`] on whichever lane scattered first) over
    /// one corpus shard. Scores must be bit-identical to
    /// [`Engine::score_corpus`] over the same candidates. Default: the
    /// same typed error as [`Engine::embed_query`].
    fn score_corpus_with(
        &mut self,
        query_hg: &[f32],
        shard: &[EncodedGraph],
    ) -> Result<CorpusOutput, EngineError> {
        let _ = (query_hg, shard);
        Err(EngineError::Unavailable {
            reason: format!("{} does not support sharded corpus scoring", self.caps().name),
        })
    }
}

/// Typed engine construction (replaces string dispatch): binds an
/// [`EngineKind`] to an artifacts directory and builds boxed engines —
/// directly via [`EngineBuilder::build`], or as a `Send` + `Sync`
/// [`EngineFactory`] for executor stages that must construct their
/// (non-`Send`) engine in-thread.
#[derive(Debug, Clone)]
pub struct EngineBuilder {
    kind: EngineKind,
    artifacts_dir: PathBuf,
    /// Embedding cache the built engine serves from, when injected.
    /// `None` means each built engine constructs its own private cache.
    cache: Option<Arc<embed_cache::EmbedCache>>,
}

impl EngineBuilder {
    /// Bind `kind` to the artifacts it loads from.
    pub fn new(kind: EngineKind, artifacts_dir: impl Into<PathBuf>) -> Self {
        EngineBuilder {
            kind,
            artifacts_dir: artifacts_dir.into(),
            cache: None,
        }
    }

    /// Inject a shared embedding cache: every engine this builder (and
    /// its clones) constructs serves from `cache` instead of a private
    /// one, so corpus candidates warmed by one lane hit on every
    /// same-kind sibling lane (DESIGN.md S15). Share caches only across
    /// lanes of the *same* [`EngineKind`]: embeddings are bit-identical
    /// across kinds built from one artifacts directory, but the cached
    /// work counters are policy-specific (a dense lane reading a
    /// sparse lane's `MacCounts` would corrupt the Table-6 comparison
    /// rows). Engines without a cache (the PJRT kinds) ignore it.
    pub fn with_embed_cache(mut self, cache: Arc<embed_cache::EmbedCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// The kind this builder constructs.
    pub fn kind(&self) -> EngineKind {
        self.kind
    }

    /// The artifacts directory engines load from.
    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Construct the engine now, in this thread.
    pub fn build(&self) -> Result<Box<dyn Engine>, EngineError> {
        let unavailable = |err: anyhow::Error| EngineError::Unavailable {
            reason: format!("constructing {} engine: {err:#}", self.kind),
        };
        let native = || -> Result<native::NativeEngine, EngineError> {
            let engine = native::NativeEngine::load(&self.artifacts_dir).map_err(unavailable)?;
            Ok(match &self.cache {
                Some(cache) => engine.with_cache(Arc::clone(cache)),
                None => engine,
            })
        };
        Ok(match self.kind {
            EngineKind::Xla => {
                Box::new(pjrt::XlaEngine::load(&self.artifacts_dir).map_err(unavailable)?)
            }
            EngineKind::XlaFused => {
                Box::new(pjrt::XlaEngine::load_fused(&self.artifacts_dir).map_err(unavailable)?)
            }
            EngineKind::Native => Box::new(native()?),
            EngineKind::NativeDense => {
                Box::new(native()?.with_policy(crate::nn::simgnn::SparsePolicy::Dense))
            }
            EngineKind::Sim => {
                let engine = crate::sim::engine::SimEngine::load(
                    &self.artifacts_dir,
                    crate::sim::config::ArchConfig::spa_gcn(),
                    crate::sim::platform::U280,
                )
                .map_err(unavailable)?;
                Box::new(match &self.cache {
                    Some(cache) => engine.with_cache(Arc::clone(cache)),
                    None => engine,
                })
            }
        })
    }

    /// Package this builder as the `Send` closure executor stages call
    /// in-thread.
    pub fn into_factory(self) -> EngineFactory {
        Arc::new(move || self.build())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::str::FromStr;

    #[test]
    fn caps_pick_batch_rounds_up_without_resort() {
        // Deliberately unsorted + duplicated input: the constructor
        // normalizes once.
        let caps = EngineCaps::new("t", vec![64, 1, 16, 4, 16], 32, 29);
        assert_eq!(caps.batch_ladder(), &[1, 4, 16, 64]);
        assert_eq!(caps.pick_batch_size(1), 1);
        assert_eq!(caps.pick_batch_size(3), 4);
        assert_eq!(caps.pick_batch_size(16), 16);
        assert_eq!(caps.pick_batch_size(17), 64);
        assert_eq!(caps.pick_batch_size(1000), 64);
        assert_eq!(caps.max_batch(), 64);
    }

    #[test]
    fn caps_flags_default_off() {
        let caps = EngineCaps::new("t", vec![1], 8, 4);
        assert!(!caps.reports_cycles && !caps.reports_exec_timing && !caps.reports_macs);
        assert!(!caps.reports_embed_cache && !caps.supports_corpus);
        assert!(!caps.supports_corpus_shards);
        let caps = caps
            .with_cycle_reports()
            .with_exec_timing()
            .with_mac_counts()
            .with_embed_cache()
            .with_corpus_scoring()
            .with_corpus_sharding();
        assert!(caps.reports_cycles && caps.reports_exec_timing && caps.reports_macs);
        assert!(caps.reports_embed_cache && caps.supports_corpus);
        assert!(caps.supports_corpus_shards);
    }

    #[test]
    fn score_corpus_default_is_a_typed_error() {
        // An engine that never opted in (no embedding cache) must answer
        // corpus queries with a typed error, not K silent full forwards.
        struct Bare(EngineCaps);
        impl Engine for Bare {
            fn caps(&self) -> &EngineCaps {
                &self.0
            }
            fn score_batch(&mut self, b: &PackedBatch) -> Result<BatchOutput, EngineError> {
                Ok(BatchOutput::untimed(vec![0.0; b.batch]))
            }
        }
        let mut e = Bare(EngineCaps::new("bare", vec![1], 8, 4));
        assert!(!e.caps().supports_corpus);
        let g = crate::graph::Graph::new(2, vec![(0, 1)], vec![0, 0]);
        let enc = crate::graph::encode::encode(&g, 8, 4).unwrap();
        let err = e.score_corpus(&enc, std::slice::from_ref(&enc)).unwrap_err();
        assert!(matches!(err, EngineError::Unavailable { ref reason } if reason.contains("bare")));
        // The sharded pair defaults to the same typed refusal.
        let err = e.embed_query(&enc).unwrap_err();
        assert!(matches!(err, EngineError::Unavailable { ref reason } if reason.contains("bare")));
        let err = e
            .score_corpus_with(&[0.0; 4], std::slice::from_ref(&enc))
            .unwrap_err();
        assert!(matches!(err, EngineError::Unavailable { ref reason } if reason.contains("bare")));
    }

    #[test]
    fn telemetry_merges_serial_sum_and_parallel_max() {
        let a = QueryTelemetry {
            cycles: Some(CycleReport { interval: 100, latency: 150 }),
            cpu_us: Some(10.0),
            macs: Some(MacCounts { macs: 5, ft_elements: 6, agg_elements: 7 }),
            embed_cache: Some(EmbedCacheTelemetry { hits: 1, misses: 2, entries: 3 }),
            ..QueryTelemetry::default()
        };
        let b = QueryTelemetry {
            cycles: Some(CycleReport { interval: 40, latency: 400 }),
            cpu_us: Some(4.0),
            macs: Some(MacCounts { macs: 50, ft_elements: 60, agg_elements: 70 }),
            embed_cache: Some(EmbedCacheTelemetry { hits: 10, misses: 20, entries: 2 }),
            ..QueryTelemetry::default()
        };
        let mut serial = a.clone();
        serial.merge_serial(&b);
        assert_eq!(serial.cycles, Some(CycleReport { interval: 140, latency: 550 }));
        assert_eq!(serial.cpu_us, Some(14.0));
        assert_eq!(serial.macs, Some(MacCounts { macs: 55, ft_elements: 66, agg_elements: 77 }));
        assert_eq!(
            serial.embed_cache,
            Some(EmbedCacheTelemetry { hits: 11, misses: 22, entries: 3 })
        );
        // Parallel: cycles take the max (shards overlap on independent
        // modeled accelerators); work counters still sum.
        let mut parallel = a.clone();
        parallel.merge_parallel(&b);
        assert_eq!(parallel.cycles, Some(CycleReport { interval: 100, latency: 400 }));
        assert_eq!(parallel.cpu_us, Some(14.0));
        assert_eq!(parallel.macs, serial.macs);
        assert_eq!(parallel.embed_cache, serial.embed_cache);
        // One side absent keeps the other, for every field.
        let mut one_sided = QueryTelemetry::default();
        one_sided.merge_parallel(&a);
        assert_eq!(one_sided, a);
        let mut keeps = a.clone();
        keeps.merge_serial(&QueryTelemetry::default());
        assert_eq!(keeps, a);
    }

    #[test]
    fn kind_roundtrips_through_strings() {
        for kind in EngineKind::ALL {
            assert_eq!(EngineKind::from_str(kind.as_str()).unwrap(), kind);
            assert_eq!(kind.to_string(), kind.as_str());
        }
    }

    #[test]
    fn parse_list_handles_lists_and_stray_commas() {
        assert_eq!(
            EngineKind::parse_list("native,sim").unwrap(),
            vec![EngineKind::Native, EngineKind::Sim]
        );
        assert_eq!(
            EngineKind::parse_list(" xla , native, ").unwrap(),
            vec![EngineKind::Xla, EngineKind::Native]
        );
        assert!(EngineKind::parse_list("native,bogus").is_err());
        assert!(EngineKind::parse_list("").is_err());
        assert!(EngineKind::parse_list(",").is_err());
    }

    #[test]
    fn kind_parse_rejects_unknown() {
        let err = EngineKind::from_str("bogus").unwrap_err();
        assert!(matches!(err, EngineError::UnknownKind(ref s) if s == "bogus"));
        let msg = err.to_string();
        for kind in EngineKind::ALL {
            assert!(msg.contains(kind.as_str()), "help list missing {kind}: {msg}");
        }
    }

    #[test]
    fn engine_errors_render() {
        let e = EngineError::UnsupportedBatch {
            batch: 7,
            ladder: vec![1, 4],
        };
        assert!(e.to_string().contains('7'));
        let e = EngineError::Backend {
            engine: "xla-pjrt".into(),
            detail: "boom".into(),
        };
        assert!(e.to_string().contains("xla-pjrt") && e.to_string().contains("boom"));
    }

    #[test]
    fn untimed_output_covers_every_slot() {
        let out = BatchOutput::untimed(vec![0.1, 0.2, 0.3]);
        assert_eq!(out.telemetry.len(), 3);
        assert!(out.telemetry.iter().all(|t| *t == QueryTelemetry::default()));
    }

    #[test]
    fn builder_reports_kind_and_dir() {
        let b = EngineBuilder::new(EngineKind::Native, "artifacts");
        assert_eq!(b.kind(), EngineKind::Native);
        assert!(b.artifacts_dir().ends_with("artifacts"));
    }
}
