//! PJRT runtime: load AOT HLO-text artifacts, compile once on the CPU
//! client, execute from the rust hot path. Mirrors the paper's deployment
//! model: the FPGA bitstream (here: compiled PJRT executable) is built
//! offline, the host only feeds inputs and collects outputs.
//!
//! Interchange is HLO *text* (see python/compile/aot.py and
//! /opt/xla-example/README.md): xla_extension 0.5.1 rejects jax>=0.5
//! serialized protos (64-bit ids), the text parser reassigns ids.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::graph::encode::PackedBatch;
use crate::nn::config::ArtifactsMeta;

use super::{BatchOutput, Engine, EngineCaps, EngineError, ExecTiming, QueryTelemetry};

/// One compiled SimGNN executable (fixed batch size).
struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    batch: usize,
}

/// The production engine: PJRT CPU client + per-batch-size executables.
/// Reports the upload/execute/download split of every chunk as
/// [`QueryTelemetry::exec`] (the "DMA write / execute / DMA read"
/// analogue of Fig. 11).
pub struct XlaEngine {
    client: xla::PjRtClient,
    executables: BTreeMap<usize, Compiled>,
    meta: ArtifactsMeta,
    artifacts_dir: PathBuf,
    caps: EngineCaps,
}

// Manual impl: the xla FFI handles (`PjRtClient`, `PjRtLoadedExecutable`)
// expose no `Debug`, so print the compiled ladder instead.
impl std::fmt::Debug for XlaEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaEngine")
            .field("artifacts_dir", &self.artifacts_dir)
            .field("batch_sizes", &self.executables.keys().collect::<Vec<_>>())
            .field("caps", &self.caps)
            .finish_non_exhaustive()
    }
}

impl XlaEngine {
    /// Load every simgnn_b*.hlo.txt listed in meta.json and compile them
    /// (the Pallas-kernel artifacts — the TPU-faithful path).
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        Self::load_variant(artifacts_dir, "simgnn")
    }

    /// Load the fused (pure-jnp, XLA-GEMM) artifact flavor — identical
    /// math, ~an order of magnitude faster on the CPU PJRT backend
    /// because interpret-mode Pallas lowers to per-grid-step loops there
    /// (EXPERIMENTS.md §Perf L2).
    pub fn load_fused(artifacts_dir: &Path) -> Result<Self> {
        Self::load_variant(artifacts_dir, "simgnn_fused")
    }

    /// Load a named artifact prefix ("simgnn" | "simgnn_fused").
    pub fn load_variant(artifacts_dir: &Path, prefix: &str) -> Result<Self> {
        let meta = ArtifactsMeta::load(artifacts_dir)
            .context("loading artifacts/meta.json (run `make artifacts`)")?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = BTreeMap::new();
        for &b in &meta.batch_sizes {
            let path = artifacts_dir.join(format!("{prefix}_b{b}.hlo.txt"));
            if !path.exists() {
                // An artifact explicitly listed in the manifest must
                // exist — a deployment missing one of its promised batch
                // sizes should fail loudly, not silently serve a reduced
                // ladder. Gaps are tolerated only for the fused flavor
                // (older artifact sets lack it) and for the defaulted
                // AOT_BATCH_LADDER fallback, where the caps ladder below
                // advertises exactly what compiled.
                anyhow::ensure!(
                    prefix != "simgnn" || !meta.ladder_from_manifest,
                    "meta.json lists batch size {b} but {} is missing",
                    path.display()
                );
                continue;
            }
            let exe = compile_hlo_text(&client, &path)
                .with_context(|| format!("compiling {}", path.display()))?;
            executables.insert(b, Compiled { exe, batch: b });
        }
        anyhow::ensure!(!executables.is_empty(), "no artifacts found for {prefix}");
        let name = if prefix == "simgnn_fused" {
            "xla-pjrt-fused"
        } else {
            "xla-pjrt"
        };
        let caps = EngineCaps::new(
            name,
            executables.keys().copied().collect(),
            meta.config.n_max,
            meta.config.num_labels,
        )
        .with_exec_timing();
        Ok(XlaEngine {
            client,
            executables,
            meta,
            artifacts_dir: artifacts_dir.to_path_buf(),
            caps,
        })
    }

    /// The artifact manifest (config + batch ladder) this engine loaded.
    pub fn meta(&self) -> &ArtifactsMeta {
        &self.meta
    }

    /// Where the HLO artifacts were loaded from.
    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// The PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile + run the gcn3 (embeddings-only) artifact once; used by the
    /// quickstart example.
    pub fn gcn3_embeddings(&self, a: &[f32], h: &[f32], m: &[f32]) -> Result<Vec<f32>> {
        let n = self.meta.config.n_max;
        let l = self.meta.config.num_labels;
        let path = self.artifacts_dir.join("gcn3_b1.hlo.txt");
        let exe = compile_hlo_text(&self.client, &path)?;
        let lits = [
            lit3(a, 1, n, n)?,
            lit3(h, 1, n, l)?,
            lit2(m, 1, n)?,
        ];
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?.to_vec::<f32>()?)
    }

    /// Wrap a backend failure with this engine's name.
    fn backend_err(&self, err: impl std::fmt::Display) -> EngineError {
        EngineError::Backend {
            engine: self.caps.name.clone(),
            detail: err.to_string(),
        }
    }
}

fn compile_hlo_text(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("non-utf8 artifact path")?,
    )?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

fn lit3(data: &[f32], b: usize, r: usize, c: usize) -> Result<xla::Literal> {
    anyhow::ensure!(data.len() == b * r * c, "literal shape mismatch");
    Ok(xla::Literal::vec1(data).reshape(&[b as i64, r as i64, c as i64])?)
}

fn lit2(data: &[f32], b: usize, r: usize) -> Result<xla::Literal> {
    anyhow::ensure!(data.len() == b * r, "literal shape mismatch");
    Ok(xla::Literal::vec1(data).reshape(&[b as i64, r as i64])?)
}

/// One PJRT launch: build the input literals ("DMA write"), execute,
/// download the scores ("DMA read"); returns the per-step timing split.
fn run_compiled(compiled: &Compiled, batch: &PackedBatch) -> Result<(Vec<f32>, ExecTiming)> {
    let (b, n, l) = (batch.batch, batch.n_max, batch.num_labels);
    let t0 = Instant::now();
    let lits = [
        lit3(&batch.a1, b, n, n)?,
        lit3(&batch.h1, b, n, l)?,
        lit2(&batch.m1, b, n)?,
        lit3(&batch.a2, b, n, n)?,
        lit3(&batch.h2, b, n, l)?,
        lit2(&batch.m2, b, n)?,
    ];
    let t1 = Instant::now();
    let outputs = compiled.exe.execute::<xla::Literal>(&lits)?;
    let t2 = Instant::now();
    // to_literal_sync is the device->host transfer (the "DMA read"); on
    // backends that execute lazily, any compute not finished by the
    // execute() return is attributed to the download at this sync point.
    let scores = outputs[0][0].to_literal_sync()?.to_tuple1()?.to_vec::<f32>()?;
    let t3 = Instant::now();
    anyhow::ensure!(scores.len() == b, "expected {b} scores, got {}", scores.len());
    let timing = ExecTiming {
        upload_us: (t1 - t0).as_secs_f64() * 1e6,
        execute_us: (t2 - t1).as_secs_f64() * 1e6,
        download_us: (t3 - t2).as_secs_f64() * 1e6,
    };
    Ok((scores, timing))
}

impl Engine for XlaEngine {
    fn caps(&self) -> &EngineCaps {
        &self.caps
    }

    fn score_batch(&mut self, batch: &PackedBatch) -> Result<BatchOutput, EngineError> {
        let compiled = self
            .executables
            .get(&batch.batch)
            .ok_or_else(|| EngineError::UnsupportedBatch {
                batch: batch.batch,
                ladder: self.caps.batch_ladder().to_vec(),
            })?;
        debug_assert_eq!(compiled.batch, batch.batch);
        let (scores, timing) =
            run_compiled(compiled, batch).map_err(|e| self.backend_err(format!("{e:#}")))?;
        // The chunk executes as one launch: every slot shares its timing.
        let telemetry = vec![
            QueryTelemetry {
                exec: Some(timing),
                ..QueryTelemetry::default()
            };
            batch.batch
        ];
        Ok(BatchOutput { scores, telemetry })
    }
}
