//! PJRT runtime: load AOT HLO-text artifacts, compile once on the CPU
//! client, execute from the rust hot path. Mirrors the paper's deployment
//! model: the FPGA bitstream (here: compiled PJRT executable) is built
//! offline, the host only feeds inputs and collects outputs.
//!
//! Interchange is HLO *text* (see python/compile/aot.py and
//! /opt/xla-example/README.md): xla_extension 0.5.1 rejects jax>=0.5
//! serialized protos (64-bit ids), the text parser reassigns ids.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::graph::encode::PackedBatch;
use crate::nn::config::ArtifactsMeta;

use super::Engine;

/// One compiled SimGNN executable (fixed batch size).
struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    batch: usize,
}

/// Timing breakdown of one execute call (for Fig. 11-style analyses).
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecTiming {
    /// Host-side input literal construction ("DMA write" analogue), µs.
    pub upload_us: f64,
    /// Device execute, µs.
    pub execute_us: f64,
    /// Output literal -> host vec ("DMA read" analogue), µs.
    pub download_us: f64,
}

/// The production engine: PJRT CPU client + per-batch-size executables.
pub struct XlaEngine {
    client: xla::PjRtClient,
    executables: BTreeMap<usize, Compiled>,
    meta: ArtifactsMeta,
    artifacts_dir: PathBuf,
    /// Timing of the most recent `score_batch` call.
    pub last_timing: ExecTiming,
}

impl XlaEngine {
    /// Load every simgnn_b*.hlo.txt listed in meta.json and compile them
    /// (the Pallas-kernel artifacts — the TPU-faithful path).
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        Self::load_variant(artifacts_dir, "simgnn")
    }

    /// Load the fused (pure-jnp, XLA-GEMM) artifact flavor — identical
    /// math, ~an order of magnitude faster on the CPU PJRT backend
    /// because interpret-mode Pallas lowers to per-grid-step loops there
    /// (EXPERIMENTS.md §Perf L2).
    pub fn load_fused(artifacts_dir: &Path) -> Result<Self> {
        Self::load_variant(artifacts_dir, "simgnn_fused")
    }

    /// Load a named artifact prefix ("simgnn" | "simgnn_fused").
    pub fn load_variant(artifacts_dir: &Path, prefix: &str) -> Result<Self> {
        let meta = ArtifactsMeta::load(artifacts_dir)
            .context("loading artifacts/meta.json (run `make artifacts`)")?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = BTreeMap::new();
        for &b in &meta.batch_sizes {
            let path = artifacts_dir.join(format!("{prefix}_b{b}.hlo.txt"));
            if !path.exists() && prefix != "simgnn" {
                continue; // older artifact sets may lack the fused flavor
            }
            let exe = compile_hlo_text(&client, &path)
                .with_context(|| format!("compiling {}", path.display()))?;
            executables.insert(b, Compiled { exe, batch: b });
        }
        anyhow::ensure!(!executables.is_empty(), "no artifacts found for {prefix}");
        Ok(XlaEngine {
            client,
            executables,
            meta,
            artifacts_dir: artifacts_dir.to_path_buf(),
            last_timing: ExecTiming::default(),
        })
    }

    pub fn meta(&self) -> &ArtifactsMeta {
        &self.meta
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile + run the gcn3 (embeddings-only) artifact once; used by the
    /// quickstart example.
    pub fn gcn3_embeddings(&self, a: &[f32], h: &[f32], m: &[f32]) -> Result<Vec<f32>> {
        let n = self.meta.config.n_max;
        let l = self.meta.config.num_labels;
        let path = self.artifacts_dir.join("gcn3_b1.hlo.txt");
        let exe = compile_hlo_text(&self.client, &path)?;
        let lits = [
            lit3(a, 1, n, n)?,
            lit3(h, 1, n, l)?,
            lit2(m, 1, n)?,
        ];
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?.to_vec::<f32>()?)
    }
}

fn compile_hlo_text(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("non-utf8 artifact path")?,
    )?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

fn lit3(data: &[f32], b: usize, r: usize, c: usize) -> Result<xla::Literal> {
    anyhow::ensure!(data.len() == b * r * c, "literal shape mismatch");
    Ok(xla::Literal::vec1(data).reshape(&[b as i64, r as i64, c as i64])?)
}

fn lit2(data: &[f32], b: usize, r: usize) -> Result<xla::Literal> {
    anyhow::ensure!(data.len() == b * r, "literal shape mismatch");
    Ok(xla::Literal::vec1(data).reshape(&[b as i64, r as i64])?)
}

impl Engine for XlaEngine {
    fn name(&self) -> &str {
        "xla-pjrt"
    }

    fn supported_batch_sizes(&self) -> Vec<usize> {
        self.executables.keys().copied().collect()
    }

    fn score_batch(&mut self, batch: &PackedBatch) -> Result<Vec<f32>> {
        let compiled = self
            .executables
            .get(&batch.batch)
            .with_context(|| format!("no artifact for batch size {}", batch.batch))?;
        debug_assert_eq!(compiled.batch, batch.batch);
        let (b, n, l) = (batch.batch, batch.n_max, batch.num_labels);

        let t0 = Instant::now();
        let lits = [
            lit3(&batch.a1, b, n, n)?,
            lit3(&batch.h1, b, n, l)?,
            lit2(&batch.m1, b, n)?,
            lit3(&batch.a2, b, n, n)?,
            lit3(&batch.h2, b, n, l)?,
            lit2(&batch.m2, b, n)?,
        ];
        let t1 = Instant::now();
        let result = compiled.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let t2 = Instant::now();
        let scores = result.to_tuple1()?.to_vec::<f32>()?;
        let t3 = Instant::now();
        self.last_timing = ExecTiming {
            upload_us: (t1 - t0).as_secs_f64() * 1e6,
            execute_us: (t2 - t1).as_secs_f64() * 1e6,
            download_us: (t3 - t2).as_secs_f64() * 1e6,
        };
        anyhow::ensure!(scores.len() == b, "expected {b} scores, got {}", scores.len());
        Ok(scores)
    }
}
