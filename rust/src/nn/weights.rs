//! Loader for artifacts/weights.bin + weights.json (see
//! python/compile/weights.py for the format). Offsets from the JSON
//! manifest are validated against the shapes implied by the config —
//! a mismatch means the python and rust sides disagree and must fail loudly.

use std::path::Path;

use crate::util::json::parse;

use super::config::ModelConfig;

/// All SimGNN weights as flat row-major f32 tensors.
#[derive(Debug, Clone)]
pub struct Weights {
    pub gcn_w: [Vec<f32>; 3],
    pub gcn_b: [Vec<f32>; 3],
    pub att_w: Vec<f32>,       // (F, F)
    pub ntn_w: Vec<f32>,       // (K, F, F)
    pub ntn_v: Vec<f32>,       // (K, 2F)
    pub ntn_b: Vec<f32>,       // (K,)
    pub fc_w: Vec<Vec<f32>>,   // [(d_i, d_{i+1})]
    pub fc_b: Vec<Vec<f32>>,   // [(d_{i+1},)]
    pub out_w: Vec<f32>,       // (d_last, 1)
    pub out_b: Vec<f32>,       // (1,)
}

impl Weights {
    /// Deterministic pseudo-random weights for any config — the
    /// artifact-free stand-in integration tests and benches use when
    /// `weights.bin` is absent. Shapes follow [`manifest_entries`], so
    /// a manifest change breaks exactly one constructor instead of a
    /// copy per test file. Same `(cfg, seed)` ⇒ bit-identical weights.
    pub fn synthetic(cfg: &ModelConfig, seed: u64) -> Weights {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut v = |len: usize, s: f32| -> Vec<f32> {
            (0..len).map(|_| (rng.f32() - 0.5) * s).collect()
        };
        let dims_in = cfg.feature_dims();
        let f = cfg.embed_dim();
        let k = cfg.ntn_k;
        let mut fc_w = Vec::new();
        let mut fc_b = Vec::new();
        let mut d = k;
        for &h in &cfg.fc_dims {
            fc_w.push(v(d * h, 0.5));
            fc_b.push(vec![0.01; h]);
            d = h;
        }
        Weights {
            gcn_w: [
                v(dims_in[0] * cfg.filters[0], 0.5),
                v(dims_in[1] * cfg.filters[1], 0.5),
                v(dims_in[2] * cfg.filters[2], 0.5),
            ],
            gcn_b: [
                vec![0.02; cfg.filters[0]],
                vec![0.02; cfg.filters[1]],
                vec![0.02; cfg.filters[2]],
            ],
            att_w: v(f * f, 0.5),
            ntn_w: v(k * f * f, 0.3),
            ntn_v: v(k * 2 * f, 0.3),
            ntn_b: vec![0.0; k],
            fc_w,
            fc_b,
            out_w: v(d, 0.5),
            out_b: vec![0.0],
        }
    }
}

/// The fixed manifest (name, shape) for a config — MUST mirror
/// python/compile/weights.py::manifest_entries.
pub fn manifest_entries(cfg: &ModelConfig) -> Vec<(String, Vec<usize>)> {
    let f3 = cfg.embed_dim();
    let k = cfg.ntn_k;
    let dims_in = cfg.feature_dims();
    let mut entries = Vec::new();
    for i in 0..3 {
        entries.push((format!("gcn_w{i}"), vec![dims_in[i], cfg.filters[i]]));
        entries.push((format!("gcn_b{i}"), vec![cfg.filters[i]]));
    }
    entries.push(("att_w".into(), vec![f3, f3]));
    entries.push(("ntn_w".into(), vec![k, f3, f3]));
    entries.push(("ntn_v".into(), vec![k, 2 * f3]));
    entries.push(("ntn_b".into(), vec![k]));
    let mut d = k;
    for (i, &h) in cfg.fc_dims.iter().enumerate() {
        entries.push((format!("fc_w{i}"), vec![d, h]));
        entries.push((format!("fc_b{i}"), vec![h]));
        d = h;
    }
    entries.push(("out_w".into(), vec![d, 1]));
    entries.push(("out_b".into(), vec![1]));
    entries
}

fn read_f32_le(path: &Path) -> anyhow::Result<Vec<f32>> {
    let bytes = std::fs::read(path)?;
    anyhow::ensure!(bytes.len() % 4 == 0, "weights.bin length not /4");
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

impl Weights {
    /// Load and validate weights from an artifacts directory.
    pub fn load(cfg: &ModelConfig, artifacts_dir: &Path) -> anyhow::Result<Self> {
        let flat = read_f32_le(&artifacts_dir.join("weights.bin"))?;
        let entries = manifest_entries(cfg);
        // Cross-check the JSON manifest if present.
        let manifest_path = artifacts_dir.join("weights.json");
        if manifest_path.exists() {
            let doc = parse(&std::fs::read_to_string(&manifest_path)?)
                .map_err(|e| anyhow::anyhow!("weights.json: {e}"))?;
            let tensors = doc
                .get("tensors")
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("weights.json missing tensors"))?;
            anyhow::ensure!(
                tensors.len() == entries.len(),
                "manifest arity mismatch: json {} vs config {}",
                tensors.len(),
                entries.len()
            );
            let mut offset = 0usize;
            for (t, (name, shape)) in tensors.iter().zip(entries.iter()) {
                anyhow::ensure!(
                    t.get("name").as_str() == Some(name.as_str()),
                    "manifest order mismatch at {name}"
                );
                let jshape: Vec<usize> = t
                    .get("shape")
                    .as_arr()
                    .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                    .unwrap_or_default();
                anyhow::ensure!(&jshape == shape, "shape mismatch for {name}");
                anyhow::ensure!(
                    t.get("offset").as_usize() == Some(offset),
                    "offset mismatch for {name}"
                );
                offset += shape.iter().product::<usize>();
            }
        }
        let total: usize = entries
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum();
        anyhow::ensure!(
            flat.len() == total,
            "weights.bin has {} floats, config implies {total}",
            flat.len()
        );
        let mut cursor = 0usize;
        let mut take = |shape: &[usize]| {
            let size: usize = shape.iter().product();
            let out = flat[cursor..cursor + size].to_vec();
            cursor += size;
            out
        };
        let gcn_w0 = take(&entries[0].1);
        let gcn_b0 = take(&entries[1].1);
        let gcn_w1 = take(&entries[2].1);
        let gcn_b1 = take(&entries[3].1);
        let gcn_w2 = take(&entries[4].1);
        let gcn_b2 = take(&entries[5].1);
        let f3 = cfg.embed_dim();
        let k = cfg.ntn_k;
        let att_w = take(&[f3, f3]);
        let ntn_w = take(&[k, f3, f3]);
        let ntn_v = take(&[k, 2 * f3]);
        let ntn_b = take(&[k]);
        let mut fc_w = Vec::new();
        let mut fc_b = Vec::new();
        let mut d = k;
        for &h in &cfg.fc_dims {
            fc_w.push(take(&[d, h]));
            fc_b.push(take(&[h]));
            d = h;
        }
        let out_w = take(&[d, 1]);
        let out_b = take(&[1]);
        assert_eq!(cursor, flat.len());
        Ok(Weights {
            gcn_w: [gcn_w0, gcn_w1, gcn_w2],
            gcn_b: [gcn_b0, gcn_b1, gcn_b2],
            att_w,
            ntn_w,
            ntn_v,
            ntn_b,
            fc_w,
            fc_b,
            out_w,
            out_b,
        })
    }

    /// Count of weight-matrix zeros — the simulator uses weight density for
    /// MULT workload estimates (weights are dense post-training, unlike
    /// activations).
    pub fn total_parameters(&self) -> usize {
        self.gcn_w.iter().map(|v| v.len()).sum::<usize>()
            + self.gcn_b.iter().map(|v| v.len()).sum::<usize>()
            + self.att_w.len()
            + self.ntn_w.len()
            + self.ntn_v.len()
            + self.ntn_b.len()
            + self.fc_w.iter().map(|v| v.len()).sum::<usize>()
            + self.fc_b.iter().map(|v| v.len()).sum::<usize>()
            + self.out_w.len()
            + self.out_b.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_matches_python_layout() {
        let cfg = ModelConfig::default();
        let entries = manifest_entries(&cfg);
        assert_eq!(entries[0], ("gcn_w0".into(), vec![29, 64]));
        assert_eq!(entries[6], ("att_w".into(), vec![16, 16]));
        assert_eq!(entries[7], ("ntn_w".into(), vec![16, 16, 16]));
        let total: usize = entries
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum();
        // 29*64+64 + 64*32+32 + 32*16+16 + 256 + 4096 + 512 + 16
        //   + 16*16+16 + 16*8+8 + 8 + 1
        assert_eq!(total, 9825);
    }
}
