//! Model configuration mirrored from python/compile/config.py via
//! artifacts/meta.json. The two sides must agree on every static shape.

use std::path::Path;

use crate::util::json::{parse, Json};

/// The batch-size ladder the AOT artifacts are compiled for
/// (python/compile writes one HLO per size). Every engine derives its
/// advertised ladder from the same `meta.json` when loading artifacts
/// and from this constant when built in-memory — one ladder source, so
/// the native/sim ladders cannot drift from the manifest PJRT compiles
/// from. (When an old `meta.json` omits the manifest entry AND the
/// artifact set is partial, PJRT advertises the subset that actually
/// compiled; the manifest, not this constant, is the contract.)
pub const AOT_BATCH_LADDER: [usize; 4] = [1, 4, 16, 64];

/// Static SimGNN configuration (see python/compile/config.py for docs).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub n_max: usize,
    pub num_labels: usize,
    pub filters: [usize; 3],
    pub relu_mask: [bool; 3],
    pub ntn_k: usize,
    pub fc_dims: Vec<usize>,
    pub seed: u64,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            n_max: 32,
            num_labels: 29,
            filters: [64, 32, 16],
            relu_mask: [true, true, false],
            ntn_k: 16,
            fc_dims: vec![16, 8],
            seed: 20210521,
        }
    }
}

impl ModelConfig {
    /// Graph-level embedding dimension F.
    pub fn embed_dim(&self) -> usize {
        self.filters[2]
    }

    /// Per-layer input feature dims [num_labels, f1, f2].
    pub fn feature_dims(&self) -> [usize; 3] {
        [self.num_labels, self.filters[0], self.filters[1]]
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        let filters = v
            .get("filters")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("missing filters"))?;
        let relu = v
            .get("relu_mask")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("missing relu_mask"))?;
        anyhow::ensure!(filters.len() == 3 && relu.len() == 3, "bad config arity");
        Ok(ModelConfig {
            n_max: v.get("n_max").as_usize().unwrap_or(32),
            num_labels: v.get("num_labels").as_usize().unwrap_or(29),
            filters: [
                filters[0].as_usize().unwrap(),
                filters[1].as_usize().unwrap(),
                filters[2].as_usize().unwrap(),
            ],
            relu_mask: [
                relu[0].as_bool().unwrap_or(true),
                relu[1].as_bool().unwrap_or(true),
                relu[2].as_bool().unwrap_or(false),
            ],
            ntn_k: v.get("ntn_k").as_usize().unwrap_or(16),
            fc_dims: v
                .get("fc_dims")
                .as_arr()
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_else(|| vec![16, 8]),
            seed: v.get("seed").as_f64().unwrap_or(0.0) as u64,
        })
    }
}

/// artifacts/meta.json: config + artifact manifest + measured sparsity.
#[derive(Debug, Clone)]
pub struct ArtifactsMeta {
    pub config: ModelConfig,
    pub batch_sizes: Vec<usize>,
    /// Whether `batch_sizes` came from an explicit
    /// `artifact_batch_sizes` manifest entry (vs the
    /// [`AOT_BATCH_LADDER`] fallback). An explicit entry is a promise
    /// the files exist: the PJRT loader hard-fails on a missing one,
    /// but tolerates gaps under the fallback (older artifact sets).
    pub ladder_from_manifest: bool,
    pub sparsity_l2: f64,
    pub sparsity_l3: f64,
}

impl ArtifactsMeta {
    pub fn load(artifacts_dir: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(artifacts_dir.join("meta.json"))?;
        let v = parse(&text).map_err(|e| anyhow::anyhow!("meta.json: {e}"))?;
        Self::from_json(&v)
    }

    /// Parse a `meta.json` document. A manifest without
    /// `artifact_batch_sizes` advertises the shared [`AOT_BATCH_LADDER`]
    /// (the ladder python/compile emits), keeping every engine on one
    /// ladder source.
    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        let config = ModelConfig::from_json(v.get("config"))?;
        let manifest_sizes = v
            .get("artifact_batch_sizes")
            .as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect::<Vec<_>>());
        let ladder_from_manifest = manifest_sizes.is_some();
        let batch_sizes = manifest_sizes.unwrap_or_else(|| AOT_BATCH_LADDER.to_vec());
        Ok(ArtifactsMeta {
            config,
            batch_sizes,
            ladder_from_manifest,
            sparsity_l2: v
                .get("sparsity")
                .get("layer2_input_sparsity")
                .as_f64()
                .unwrap_or(0.5),
            sparsity_l3: v
                .get("sparsity")
                .get("layer3_input_sparsity")
                .as_f64()
                .unwrap_or(0.5),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_python_defaults() {
        let c = ModelConfig::default();
        assert_eq!(c.n_max, 32);
        assert_eq!(c.num_labels, 29);
        assert_eq!(c.filters, [64, 32, 16]);
        assert_eq!(c.embed_dim(), 16);
        assert_eq!(c.feature_dims(), [29, 64, 32]);
    }

    #[test]
    fn meta_without_ladder_defaults_to_shared_constant() {
        let v = parse(
            r#"{"config": {"filters": [64, 32, 16],
                "relu_mask": [true, true, false]}}"#,
        )
        .unwrap();
        let meta = ArtifactsMeta::from_json(&v).unwrap();
        assert_eq!(meta.batch_sizes, AOT_BATCH_LADDER.to_vec());
        assert!(!meta.ladder_from_manifest, "fallback ladder is not a promise");
        // An explicit manifest ladder wins over the constant.
        let v = parse(
            r#"{"config": {"filters": [64, 32, 16],
                "relu_mask": [true, true, false]},
                "artifact_batch_sizes": [1, 8]}"#,
        )
        .unwrap();
        let meta = ArtifactsMeta::from_json(&v).unwrap();
        assert_eq!(meta.batch_sizes, vec![1, 8]);
        assert!(meta.ladder_from_manifest, "explicit ladder is a promise");
    }

    #[test]
    fn parse_config_json() {
        let v = parse(
            r#"{"n_max": 16, "num_labels": 8, "filters": [4, 4, 2],
                "relu_mask": [true, false, false], "ntn_k": 4,
                "fc_dims": [4], "seed": 1}"#,
        )
        .unwrap();
        let c = ModelConfig::from_json(&v).unwrap();
        assert_eq!(c.n_max, 16);
        assert_eq!(c.filters, [4, 4, 2]);
        assert_eq!(c.relu_mask, [true, false, false]);
        assert_eq!(c.fc_dims, vec![4]);
    }
}
