//! Vectorized f32 kernel layer behind one dispatch point (DESIGN.md S16).
//!
//! Every hot-path kernel of the serving stack — CSR aggregation, the
//! one-hot / nonzero-skipping feature transforms, the FCN matvecs and
//! the NTN bilinear form — exists twice:
//!
//!  * **scalar** ([`scalar`]): thin delegations to the reference loops
//!    in [`super::linalg`] (plus scalar compositions for the fused
//!    kernels). This is the bit-exact baseline every property test
//!    measures against.
//!  * **lanes** ([`lanes`]): fixed-width `[f32; LANE_WIDTH]` lane ops
//!    on stable Rust. The inner loops run over `chunks_exact` blocks
//!    with compile-time-known trip counts, the shape LLVM's
//!    autovectorizer reliably lowers to SIMD — no nightly
//!    `portable_simd`, no arch intrinsics, identical results on every
//!    target. `csr_spmm` additionally schedules rows through an
//!    nnz-bucketed order (FlexVector-style occupancy grouping, see
//!    [`lanes::nnz_bucket_order`]) and `ntn_bilinear` register-blocks
//!    [`lanes::ROW_BLOCK`] rows of W_k against one pass over `hg2`.
//!
//! Both variants are ALWAYS compiled; the `simd` cargo feature (on by
//! default) only selects which one the top-level dispatchers run, and
//! [`set_kernel_path`] overrides that choice at runtime (the serving
//! CLI's `--kernels scalar` escape hatch). `nn/simgnn.rs` calls the
//! dispatchers exclusively — the ARCH-LINALG-CONFINED lint rule keeps
//! direct scalar-kernel calls out of the hot path — so `NativeEngine`, the embed cache, and
//! sharded corpus scoring all inherit the active path.
//!
//! # Numerical contracts (enforced by `rust/tests/simd_parity.rs`)
//!
//! | kernel              | contract                                      |
//! |---------------------|-----------------------------------------------|
//! | `csr_spmm`          | bit-identical to scalar (row scheduling permutes rows, never within-row accumulation order) |
//! | `onehot_gather`     | bit-identical (single weight-row scale)        |
//! | `sparse_row_matmul` | bit-identical (k-outer / lane-inner preserves per-element order) |
//! | `vec_mat`           | bit-identical (same loop shape as `matmul`'s 1-row case, zero-skip included) |
//! | `dot` / `matvec`    | reassociates into `LANE_WIDTH` partial sums: within [`REASSOC_EPS_REL`] relative |
//! | `ntn_bilinear`      | reassociates per row-dot: within [`REASSOC_EPS_REL`] relative |
//!
//! Bit-identity holds because each lane element performs exactly the
//! scalar loop's `acc += a * x` in the same index order, and rustc does
//! not contract separate mul + add into an FMA. MAC counts are computed
//! from the same closed forms on both paths, so work telemetry is
//! identical regardless of the active path.

use std::sync::atomic::{AtomicU8, Ordering};

use super::linalg;

/// Fixed vector width of the lanes path: one `[f32; 8]` register tile
/// (256-bit — a full AVX2 register, two NEON registers).
pub const LANE_WIDTH: usize = 8;

/// Relative error bound for the reassociating kernels (`dot`, `matvec`,
/// `ntn_bilinear`): `|lanes − scalar| ≤ REASSOC_EPS_REL · (1 + |scalar|)`
/// per element. Pinned by `rust/tests/simd_parity.rs`; generous for the
/// ≤ 64-element reductions this model runs (observed error is ~1e-7).
pub const REASSOC_EPS_REL: f32 = 1e-5;

/// Which implementation the top-level dispatchers run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelPath {
    /// Reference scalar loops (`nn/linalg.rs`).
    Scalar,
    /// Fixed-width lane kernels with nnz-bucketed SpMM scheduling.
    Lanes,
}

impl KernelPath {
    /// The stable CLI/report spelling of this path.
    pub fn as_str(&self) -> &'static str {
        match self {
            KernelPath::Scalar => "scalar",
            KernelPath::Lanes => "lanes",
        }
    }

    /// The compile-time default: `Lanes` when the `simd` feature is on
    /// (it is by default), `Scalar` under `--no-default-features`.
    pub const fn compiled_default() -> KernelPath {
        if cfg!(feature = "simd") {
            KernelPath::Lanes
        } else {
            KernelPath::Scalar
        }
    }

    fn from_u8(v: u8) -> KernelPath {
        if v == 1 {
            KernelPath::Lanes
        } else {
            KernelPath::Scalar
        }
    }

    fn to_u8(self) -> u8 {
        match self {
            KernelPath::Scalar => 0,
            KernelPath::Lanes => 1,
        }
    }
}

/// Process-wide active path, initialized from the `simd` feature.
static ACTIVE: AtomicU8 = AtomicU8::new(if cfg!(feature = "simd") { 1 } else { 0 });

/// The path the dispatchers currently run.
pub fn active_path() -> KernelPath {
    KernelPath::from_u8(ACTIVE.load(Ordering::Relaxed))
}

/// Override the active path process-wide (the scalar fallback selector).
/// Scores move by at most the reassociation epsilon; callers that
/// compare both paths in one process (benches, parity tests) must
/// restore [`KernelPath::compiled_default`] afterwards.
pub fn set_kernel_path(path: KernelPath) {
    ACTIVE.store(path.to_u8(), Ordering::Relaxed);
}

// ---------------------------------------------------------------------
// Dispatchers — the only kernel entry points nn/simgnn.rs may call.
// ---------------------------------------------------------------------

/// Sparse aggregation `out = CSR(A') @ x`; see [`linalg::csr_spmm`] for
/// the shape/MAC contract. Bit-identical across paths.
pub fn csr_spmm(
    indptr: &[u32],
    indices: &[u16],
    weights: &[f32],
    x: &[f32],
    rows_out: usize,
    f: usize,
) -> (Vec<f32>, u64) {
    match active_path() {
        KernelPath::Scalar => scalar::csr_spmm(indptr, indices, weights, x, rows_out, f),
        KernelPath::Lanes => lanes::csr_spmm(indptr, indices, weights, x, rows_out, f),
    }
}

/// Layer-0 one-hot feature transform; see [`linalg::onehot_gather`].
/// Bit-identical across paths.
pub fn onehot_gather(
    h: &[f32],
    w: &[f32],
    rows: usize,
    rows_out: usize,
    f_in: usize,
    f_out: usize,
) -> (Vec<f32>, u64, u64) {
    match active_path() {
        KernelPath::Scalar => scalar::onehot_gather(h, w, rows, rows_out, f_in, f_out),
        KernelPath::Lanes => lanes::onehot_gather(h, w, rows, rows_out, f_in, f_out),
    }
}

/// Nonzero-skipping feature transform; see [`linalg::sparse_row_matmul`].
/// Bit-identical across paths.
pub fn sparse_row_matmul(
    h: &[f32],
    w: &[f32],
    rows: usize,
    rows_out: usize,
    f_in: usize,
    f_out: usize,
) -> (Vec<f32>, u64, u64) {
    match active_path() {
        KernelPath::Scalar => scalar::sparse_row_matmul(h, w, rows, rows_out, f_in, f_out),
        KernelPath::Lanes => lanes::sparse_row_matmul(h, w, rows, rows_out, f_in, f_out),
    }
}

/// FCN layer step `y[h] = x[1,d] @ w[d,h]` (bias/activation excluded).
/// Bit-identical across paths.
pub fn vec_mat(x: &[f32], w: &[f32], d: usize, h: usize) -> Vec<f32> {
    match active_path() {
        KernelPath::Scalar => scalar::vec_mat(x, w, d, h),
        KernelPath::Lanes => lanes::vec_mat(x, w, d, h),
    }
}

/// `out[m] = a[m,n] @ x[n]`. Epsilon contract ([`REASSOC_EPS_REL`]).
pub fn matvec(a: &[f32], x: &[f32], m: usize, n: usize) -> Vec<f32> {
    match active_path() {
        KernelPath::Scalar => scalar::matvec(a, x, m, n),
        KernelPath::Lanes => lanes::matvec(a, x, m, n),
    }
}

/// Inner product. Epsilon contract ([`REASSOC_EPS_REL`]).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    match active_path() {
        KernelPath::Scalar => scalar::dot(a, b),
        KernelPath::Lanes => lanes::dot(a, b),
    }
}

/// One NTN slice's bilinear form `hg1ᵀ W_k hg2` (Eq. 4). Epsilon
/// contract ([`REASSOC_EPS_REL`]); register-blocked on the lanes path.
pub fn ntn_bilinear(wk: &[f32], hg1: &[f32], hg2: &[f32], f: usize) -> f32 {
    match active_path() {
        KernelPath::Scalar => scalar::ntn_bilinear(wk, hg1, hg2, f),
        KernelPath::Lanes => lanes::ntn_bilinear(wk, hg1, hg2, f),
    }
}

// ---------------------------------------------------------------------
// Scalar path: the reference loops, under one roof.
// ---------------------------------------------------------------------

/// Reference scalar implementations — delegations to [`linalg`] plus
/// scalar compositions of the fused kernels. The parity baseline.
pub mod scalar {
    use super::linalg;

    pub fn csr_spmm(
        indptr: &[u32],
        indices: &[u16],
        weights: &[f32],
        x: &[f32],
        rows_out: usize,
        f: usize,
    ) -> (Vec<f32>, u64) {
        linalg::csr_spmm(indptr, indices, weights, x, rows_out, f)
    }

    pub fn onehot_gather(
        h: &[f32],
        w: &[f32],
        rows: usize,
        rows_out: usize,
        f_in: usize,
        f_out: usize,
    ) -> (Vec<f32>, u64, u64) {
        linalg::onehot_gather(h, w, rows, rows_out, f_in, f_out)
    }

    pub fn sparse_row_matmul(
        h: &[f32],
        w: &[f32],
        rows: usize,
        rows_out: usize,
        f_in: usize,
        f_out: usize,
    ) -> (Vec<f32>, u64, u64) {
        linalg::sparse_row_matmul(h, w, rows, rows_out, f_in, f_out)
    }

    /// `x[1,d] @ w[d,h]` via the reference matmul's one-row case.
    pub fn vec_mat(x: &[f32], w: &[f32], d: usize, h: usize) -> Vec<f32> {
        linalg::matmul(x, w, 1, d, h)
    }

    pub fn matvec(a: &[f32], x: &[f32], m: usize, n: usize) -> Vec<f32> {
        linalg::matvec(a, x, m, n)
    }

    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        linalg::dot(a, b)
    }

    /// The unfused reference composition: `dot(hg1, W_k @ hg2)`.
    pub fn ntn_bilinear(wk: &[f32], hg1: &[f32], hg2: &[f32], f: usize) -> f32 {
        assert_eq!(wk.len(), f * f, "W_k shape");
        linalg::dot(hg1, &linalg::matvec(wk, hg2, f, f))
    }
}

// ---------------------------------------------------------------------
// Lanes path: fixed-width vector kernels.
// ---------------------------------------------------------------------

/// Fixed-width lane kernels. Public so benches and parity tests can pin
/// this path explicitly regardless of the process-wide dispatch state.
pub mod lanes {
    use super::{linalg, LANE_WIDTH};

    /// `acc[i] += a * x[i]`, lane-chunked. Each element still performs
    /// exactly one multiply and one add in index order, so callers built
    /// on this stay bit-identical to their scalar twins.
    #[inline]
    fn axpy(acc: &mut [f32], a: f32, x: &[f32]) {
        debug_assert_eq!(acc.len(), x.len());
        let mut oi = acc.chunks_exact_mut(LANE_WIDTH);
        let mut xi = x.chunks_exact(LANE_WIDTH);
        for (o, xs) in oi.by_ref().zip(xi.by_ref()) {
            for l in 0..LANE_WIDTH {
                o[l] += a * xs[l];
            }
        }
        for (o, &xv) in oi.into_remainder().iter_mut().zip(xi.remainder()) {
            *o += a * xv;
        }
    }

    /// Pinned pairwise reduction of one lane register. The fixed tree
    /// makes the lanes `dot` deterministic across calls and targets.
    #[inline]
    fn hsum(acc: [f32; LANE_WIDTH]) -> f32 {
        ((acc[0] + acc[4]) + (acc[2] + acc[6])) + ((acc[1] + acc[5]) + (acc[3] + acc[7]))
    }

    /// Power-of-two nnz class of one row: 0 → 0, 1 → 1, 2 → 2, 3–4 → 3,
    /// 5–8 → 4, … (class = bit length of nnz, with 3..=4 style ranges
    /// from rounding up). Rows in one class share an inner trip-count
    /// regime, the software analogue of keeping vector lanes full.
    #[inline]
    pub fn nnz_class(nnz: u32) -> usize {
        (u32::BITS - nnz.leading_zeros()) as usize
    }

    /// FlexVector-style row schedule: rows grouped by [`nnz_class`],
    /// ascending row id within a class (stable counting sort, so the
    /// schedule is deterministic). Scheduling permutes whole rows only;
    /// each output row's accumulation order is untouched, which is why
    /// the bucketed SpMM stays bit-identical to the scalar one.
    pub fn nnz_bucket_order(indptr: &[u32]) -> Vec<u32> {
        const CLASSES: usize = (u32::BITS + 1) as usize;
        let rows = indptr.len() - 1;
        let mut counts = [0usize; CLASSES];
        for r in 0..rows {
            counts[nnz_class(indptr[r + 1] - indptr[r])] += 1;
        }
        let mut offsets = [0usize; CLASSES];
        let mut acc = 0;
        for (c, &n) in counts.iter().enumerate() {
            offsets[c] = acc;
            acc += n;
        }
        let mut order = vec![0u32; rows];
        for r in 0..rows {
            let c = nnz_class(indptr[r + 1] - indptr[r]);
            order[offsets[c]] = r as u32;
            offsets[c] += 1;
        }
        order
    }

    /// nnz-bucketed, lane-vectorized CSR SpMM. Same contract as
    /// [`linalg::csr_spmm`], bit-identical output.
    pub fn csr_spmm(
        indptr: &[u32],
        indices: &[u16],
        weights: &[f32],
        x: &[f32],
        rows_out: usize,
        f: usize,
    ) -> (Vec<f32>, u64) {
        linalg::check_csr_inputs(indptr, indices, weights, x, rows_out, f);
        let mut out = vec![0.0f32; rows_out * f];
        for &r in &nnz_bucket_order(indptr) {
            let r = r as usize;
            let (s, t) = (indptr[r] as usize, indptr[r + 1] as usize);
            if s == t {
                continue; // empty row: output stays zero, like scalar
            }
            let orow = &mut out[r * f..(r + 1) * f];
            for k in s..t {
                let col = indices[k] as usize;
                axpy(orow, weights[k], &x[col * f..(col + 1) * f]);
            }
        }
        (out, indices.len() as u64 * f as u64)
    }

    /// Lane-vectorized one-hot gather. Same contract as
    /// [`linalg::onehot_gather`], bit-identical output.
    pub fn onehot_gather(
        h: &[f32],
        w: &[f32],
        rows: usize,
        rows_out: usize,
        f_in: usize,
        f_out: usize,
    ) -> (Vec<f32>, u64, u64) {
        assert!(rows <= rows_out);
        assert_eq!(w.len(), f_in * f_out, "w shape");
        let mut out = vec![0.0f32; rows_out * f_out];
        let mut nnz = 0u64;
        for i in 0..rows {
            let hrow = &h[i * f_in..(i + 1) * f_in];
            let Some(lab) = hrow.iter().position(|&x| x != 0.0) else {
                continue;
            };
            debug_assert!(
                hrow[lab + 1..].iter().all(|&x| x == 0.0),
                "row {i} is not one-hot"
            );
            nnz += 1;
            axpy(
                &mut out[i * f_out..(i + 1) * f_out],
                hrow[lab],
                &w[lab * f_out..(lab + 1) * f_out],
            );
        }
        (out, nnz, nnz * f_out as u64)
    }

    /// Lane-vectorized nonzero-skipping FT. Same contract as
    /// [`linalg::sparse_row_matmul`], bit-identical output.
    pub fn sparse_row_matmul(
        h: &[f32],
        w: &[f32],
        rows: usize,
        rows_out: usize,
        f_in: usize,
        f_out: usize,
    ) -> (Vec<f32>, u64, u64) {
        assert!(rows <= rows_out);
        assert_eq!(w.len(), f_in * f_out, "w shape");
        let mut out = vec![0.0f32; rows_out * f_out];
        let mut nnz = 0u64;
        for i in 0..rows {
            let hrow = &h[i * f_in..(i + 1) * f_in];
            let orow = &mut out[i * f_out..(i + 1) * f_out];
            for (k, &hv) in hrow.iter().enumerate() {
                if hv == 0.0 {
                    continue;
                }
                nnz += 1;
                axpy(orow, hv, &w[k * f_out..(k + 1) * f_out]);
            }
        }
        (out, nnz, nnz * f_out as u64)
    }

    /// Lane-vectorized `x[1,d] @ w[d,h]`. k-outer / lane-inner keeps each
    /// output element's accumulation order equal to the scalar matmul's
    /// zero-skipping one-row case: bit-identical.
    pub fn vec_mat(x: &[f32], w: &[f32], d: usize, h: usize) -> Vec<f32> {
        assert_eq!(x.len(), d, "x shape");
        assert_eq!(w.len(), d * h, "w shape");
        let mut y = vec![0.0f32; h];
        for (k, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue; // match matmul's zero-skip exactly
            }
            axpy(&mut y, xv, &w[k * h..(k + 1) * h]);
        }
        y
    }

    /// Lane-partial inner product: `LANE_WIDTH` parallel accumulators,
    /// one pinned horizontal reduction, scalar tail. Reassociates —
    /// epsilon contract ([`super::REASSOC_EPS_REL`]).
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        assert_eq!(a.len(), b.len());
        let mut acc = [0.0f32; LANE_WIDTH];
        let mut ai = a.chunks_exact(LANE_WIDTH);
        let mut bi = b.chunks_exact(LANE_WIDTH);
        for (xs, ys) in ai.by_ref().zip(bi.by_ref()) {
            for l in 0..LANE_WIDTH {
                acc[l] += xs[l] * ys[l];
            }
        }
        let mut tail = 0.0f32;
        for (&xv, &yv) in ai.remainder().iter().zip(bi.remainder()) {
            tail += xv * yv;
        }
        hsum(acc) + tail
    }

    /// Row-wise lanes [`dot`]. Epsilon contract.
    pub fn matvec(a: &[f32], x: &[f32], m: usize, n: usize) -> Vec<f32> {
        assert_eq!(a.len(), m * n);
        assert_eq!(x.len(), n);
        (0..m).map(|i| dot(&a[i * n..(i + 1) * n], x)).collect()
    }

    /// Rows of W_k processed per register block in [`ntn_bilinear`]:
    /// `ROW_BLOCK` lane accumulators live at once (4 × 8 = one 32-slot
    /// register tile), and `hg2` streams through registers once per
    /// block instead of once per row.
    pub const ROW_BLOCK: usize = 4;

    /// Register-blocked bilinear form `hg1ᵀ W_k hg2`. Epsilon contract:
    /// each row-dot reassociates like [`dot`]; the final sum over rows
    /// runs in ascending row order, the same order as the scalar
    /// `dot(hg1, W_k @ hg2)` composition.
    pub fn ntn_bilinear(wk: &[f32], hg1: &[f32], hg2: &[f32], f: usize) -> f32 {
        assert_eq!(wk.len(), f * f, "W_k shape");
        assert_eq!(hg1.len(), f, "hg1 shape");
        assert_eq!(hg2.len(), f, "hg2 shape");
        let chunks = f / LANE_WIDTH;
        let mut sum = 0.0f32;
        let mut i = 0;
        while i < f {
            let rows = (f - i).min(ROW_BLOCK);
            let mut acc = [[0.0f32; LANE_WIDTH]; ROW_BLOCK];
            for c in 0..chunks {
                let xs = &hg2[c * LANE_WIDTH..(c + 1) * LANE_WIDTH];
                for (r, arow) in acc.iter_mut().enumerate().take(rows) {
                    let base = (i + r) * f + c * LANE_WIDTH;
                    let ws = &wk[base..base + LANE_WIDTH];
                    for l in 0..LANE_WIDTH {
                        arow[l] += ws[l] * xs[l];
                    }
                }
            }
            for (r, arow) in acc.into_iter().enumerate().take(rows) {
                let mut rd = hsum(arow);
                for j in chunks * LANE_WIDTH..f {
                    rd += wk[(i + r) * f + j] * hg2[j];
                }
                sum += hg1[i + r] * rd;
            }
            i += rows;
        }
        sum
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_path_follows_feature_flag() {
        // Unit tests never toggle the global path (other lib tests score
        // concurrently in this process); simd_parity.rs owns toggling.
        assert_eq!(active_path(), KernelPath::compiled_default());
        let want = if cfg!(feature = "simd") {
            KernelPath::Lanes
        } else {
            KernelPath::Scalar
        };
        assert_eq!(KernelPath::compiled_default(), want);
        assert_eq!(KernelPath::Scalar.as_str(), "scalar");
        assert_eq!(KernelPath::Lanes.as_str(), "lanes");
    }

    #[test]
    fn nnz_classes_are_power_of_two_ranges() {
        assert_eq!(lanes::nnz_class(0), 0);
        assert_eq!(lanes::nnz_class(1), 1);
        assert_eq!(lanes::nnz_class(2), 2);
        assert_eq!(lanes::nnz_class(3), 2);
        assert_eq!(lanes::nnz_class(4), 3);
        assert_eq!(lanes::nnz_class(7), 3);
        assert_eq!(lanes::nnz_class(8), 4);
        assert_eq!(lanes::nnz_class(9), 4);
        assert_eq!(lanes::nnz_class(16), 5);
    }

    #[test]
    fn bucket_order_is_a_stable_class_grouped_permutation() {
        // Rows with nnz 3,0,1,8,2,1 → classes 2,0,1,4,2,1: expect class
        // groups ascending, row ids ascending within each group.
        let indptr = vec![0u32, 3, 3, 4, 12, 14, 15];
        let order = lanes::nnz_bucket_order(&indptr);
        assert_eq!(order, vec![1, 2, 5, 0, 4, 3]);
        // Permutation property.
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4, 5]);
    }

    /// Tiny CSR of [[0.5, 0.2, 0], [0, 0.9, 0]] padded to 3 output rows
    /// (mirrors linalg's fixture so the two test suites cross-check).
    fn tiny_csr() -> (Vec<u32>, Vec<u16>, Vec<f32>) {
        (vec![0, 2, 3], vec![0, 1, 1], vec![0.5, 0.2, 0.9])
    }

    #[test]
    fn lanes_csr_spmm_bit_matches_scalar() {
        let (indptr, indices, weights) = tiny_csr();
        // f = 9 exercises one full lane + a 1-element tail.
        let f = 9;
        let x: Vec<f32> = (0..3 * f).map(|i| (i as f32 - 10.0) * 0.37).collect();
        let (want, wm) = scalar::csr_spmm(&indptr, &indices, &weights, &x, 3, f);
        let (got, gm) = lanes::csr_spmm(&indptr, &indices, &weights, &x, 3, f);
        assert_eq!(got, want);
        assert_eq!(gm, wm);
    }

    #[test]
    fn lanes_vec_mat_bit_matches_matmul_row() {
        let d = 11;
        let h = 13;
        let x: Vec<f32> = (0..d).map(|i| if i % 3 == 0 { 0.0 } else { i as f32 * 0.2 }).collect();
        let w: Vec<f32> = (0..d * h).map(|i| ((i % 17) as f32 - 8.0) * 0.05).collect();
        assert_eq!(lanes::vec_mat(&x, &w, d, h), scalar::vec_mat(&x, &w, d, h));
    }

    #[test]
    fn lanes_dot_within_reassociation_epsilon() {
        for n in [1usize, 7, 8, 9, 16, 63, 64, 65] {
            let a: Vec<f32> = (0..n).map(|i| ((i * 7 % 23) as f32 - 11.0) * 0.13).collect();
            let b: Vec<f32> = (0..n).map(|i| ((i * 5 % 19) as f32 - 9.0) * 0.21).collect();
            let s = scalar::dot(&a, &b);
            let l = lanes::dot(&a, &b);
            assert!(
                (l - s).abs() <= REASSOC_EPS_REL * (1.0 + s.abs()),
                "n={n}: lanes {l} vs scalar {s}"
            );
        }
    }

    #[test]
    fn ntn_bilinear_blocks_cover_non_multiple_dims() {
        // f = 10: one 8-lane chunk + tail, and a 4+4+2 row blocking.
        let f = 10;
        let wk: Vec<f32> = (0..f * f).map(|i| ((i % 29) as f32 - 14.0) * 0.03).collect();
        let hg1: Vec<f32> = (0..f).map(|i| (i as f32 - 4.0) * 0.11).collect();
        let hg2: Vec<f32> = (0..f).map(|i| (i as f32 - 6.0) * 0.09).collect();
        let s = scalar::ntn_bilinear(&wk, &hg1, &hg2, f);
        let l = lanes::ntn_bilinear(&wk, &hg1, &hg2, f);
        assert!(
            (l - s).abs() <= REASSOC_EPS_REL * (1.0 + s.abs()),
            "lanes {l} vs scalar {s}"
        );
    }

    #[test]
    #[should_panic(expected = "CSR column")]
    fn lanes_csr_spmm_rejects_out_of_range_column() {
        // Column 5 with an x of only 2 rows: the old `x.len() % f == 0`
        // check passed vacuously; the shared validation must panic.
        let (got, _) = lanes::csr_spmm(&[0, 1], &[5], &[1.0], &[1.0, 2.0, 3.0, 4.0], 1, 2);
        std::hint::black_box(got);
    }
}
