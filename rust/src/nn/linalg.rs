//! Small dense f32 linear algebra for the independent rust reference
//! (nn::simgnn) and the simulator's functional model. Row-major, no
//! external BLAS — the matrices here are at most 64x64.

/// out[m,n] = a[m,k] @ b[k,n]  (row-major, accumulate in f32).
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "a shape");
    assert_eq!(b.len(), k * n, "b shape");
    let mut out = vec![0.0f32; m * n];
    // ikj loop order: streams b row-wise, vectorizer-friendly.
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // sparse-friendly: skip zero activations
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
    out
}

/// Sparse aggregation: `out[rows_out, f] = CSR(A') @ x`, touching only
/// the `indptr.len() - 1` real rows (padded rows of `out` stay zero, so
/// downstream masked stages see exactly what the dense path produces).
/// Column indices ascend within each row — the same accumulation order
/// as [`matmul`]'s zero-skipping inner loop, so the two paths agree
/// bit-for-bit, not just within tolerance.
///
/// Returns the output and the MAC count (`nnz * f`) — the software
/// analogue of the paper's Table 6 "useful work" accounting.
pub fn csr_spmm(
    indptr: &[u32],
    indices: &[u16],
    weights: &[f32],
    x: &[f32],
    rows_out: usize,
    f: usize,
) -> (Vec<f32>, u64) {
    check_csr_inputs(indptr, indices, weights, x, rows_out, f);
    let mut out = vec![0.0f32; rows_out * f];
    for i in 0..indptr.len() - 1 {
        let orow = &mut out[i * f..(i + 1) * f];
        for k in indptr[i] as usize..indptr[i + 1] as usize {
            let w = weights[k];
            let xrow = &x[indices[k] as usize * f..(indices[k] as usize + 1) * f];
            for (o, &xv) in orow.iter_mut().zip(xrow.iter()) {
                *o += w * xv;
            }
        }
    }
    let macs = indices.len() as u64 * f as u64;
    (out, macs)
}

/// Shared CSR input validation for both `csr_spmm` implementations
/// (scalar here, lanes in `nn/kernels.rs`). Hard asserts, not
/// debug_asserts: the old `debug_assert_eq!(x.len() % f, 0)` passed
/// vacuously for an `x` too short to cover the CSR's column range, and
/// an out-of-range column index must fail the same way in release
/// builds as in tests.
pub(crate) fn check_csr_inputs(
    indptr: &[u32],
    indices: &[u16],
    weights: &[f32],
    x: &[f32],
    rows_out: usize,
    f: usize,
) {
    assert_eq!(indices.len(), weights.len(), "CSR indices/weights length mismatch");
    assert!(
        !indptr.is_empty() && indptr.len() - 1 <= rows_out,
        "CSR has {} rows, output holds {rows_out}",
        indptr.len().max(1) - 1
    );
    assert_eq!(
        *indptr.last().unwrap() as usize,
        indices.len(),
        "CSR indptr tail disagrees with nnz"
    );
    assert!(f == 0 || x.len() % f == 0, "x length {} not a multiple of f={f}", x.len());
    if let Some(&max_col) = indices.iter().max() {
        // The real fix for the vacuous length check: x must actually
        // cover the maximum column index the CSR will gather from.
        assert!(
            (max_col as usize + 1) * f <= x.len(),
            "CSR column {max_col} out of range: x covers only {} rows of {f}",
            if f == 0 { 0 } else { x.len() / f }
        );
    }
}

/// Layer-0 feature transform for one-hot inputs: row `i` of the output
/// is `h[i, lab] * w[lab, :]` — a row-select from the weight matrix
/// instead of a full `H @ W` (the paper's §3.4 one-hot shortcut). Only
/// the first `rows` rows are touched; the rest of the `rows_out x f_out`
/// output stays zero. All-zero rows (possible only on corrupted input —
/// encode always emits one-hot rows) select nothing and stay zero, which
/// matches the dense matmul exactly.
///
/// Returns `(out, nonzeros, macs)`: one nonzero and `f_out` MACs per
/// selecting row.
pub fn onehot_gather(
    h: &[f32],
    w: &[f32],
    rows: usize,
    rows_out: usize,
    f_in: usize,
    f_out: usize,
) -> (Vec<f32>, u64, u64) {
    assert!(rows <= rows_out);
    assert_eq!(w.len(), f_in * f_out, "w shape");
    let mut out = vec![0.0f32; rows_out * f_out];
    let mut nnz = 0u64;
    for i in 0..rows {
        let hrow = &h[i * f_in..(i + 1) * f_in];
        let Some(lab) = hrow.iter().position(|&x| x != 0.0) else {
            continue;
        };
        debug_assert!(
            hrow[lab + 1..].iter().all(|&x| x == 0.0),
            "row {i} is not one-hot"
        );
        nnz += 1;
        let v = hrow[lab];
        let wrow = &w[lab * f_out..(lab + 1) * f_out];
        for (o, &wv) in out[i * f_out..(i + 1) * f_out].iter_mut().zip(wrow.iter()) {
            *o += v * wv;
        }
    }
    (out, nnz, nnz * f_out as u64)
}

/// Nonzero-skipping feature transform over the real rows only: the
/// software twin of the sparse FT engine's pruning unit — it consumes
/// exactly the elements `sim::ft::nonzero_stream` would dispatch
/// (`h[v, k] != 0` for `v < rows`) and never touches padded rows.
/// Accumulation order per output row matches [`matmul`]'s zero-skip
/// loop, so scores agree bit-for-bit with the dense path.
///
/// Returns `(out, nonzeros, macs)` with `macs = nonzeros * f_out`.
pub fn sparse_row_matmul(
    h: &[f32],
    w: &[f32],
    rows: usize,
    rows_out: usize,
    f_in: usize,
    f_out: usize,
) -> (Vec<f32>, u64, u64) {
    assert!(rows <= rows_out);
    assert_eq!(w.len(), f_in * f_out, "w shape");
    let mut out = vec![0.0f32; rows_out * f_out];
    let mut nnz = 0u64;
    for i in 0..rows {
        let hrow = &h[i * f_in..(i + 1) * f_in];
        let orow = &mut out[i * f_out..(i + 1) * f_out];
        for (k, &hv) in hrow.iter().enumerate() {
            if hv == 0.0 {
                continue;
            }
            nnz += 1;
            let wrow = &w[k * f_out..(k + 1) * f_out];
            for (o, &wv) in orow.iter_mut().zip(wrow.iter()) {
                *o += hv * wv;
            }
        }
    }
    (out, nnz, nnz * f_out as u64)
}

/// out[m] = a[m,n] @ x[n]
pub fn matvec(a: &[f32], x: &[f32], m: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), n);
    (0..m)
        .map(|i| {
            a[i * n..(i + 1) * n]
                .iter()
                .zip(x.iter())
                .map(|(&av, &xv)| av * xv)
                .sum()
        })
        .collect()
}

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b.iter()).map(|(&x, &y)| x * y).sum()
}

pub fn relu_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

pub fn tanh_vec(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.tanh();
    }
}

/// Fraction of exact zeros in a slice (sparsity measurement, §3.4).
pub fn sparsity(x: &[f32]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().filter(|&&v| v == 0.0).count() as f64 / x.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let i = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &i, 2, 2, 2), a);
        assert_eq!(matmul(&i, &a, 2, 2, 2), a);
    }

    #[test]
    fn matmul_known() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![5.0, 6.0, 7.0, 8.0];
        assert_eq!(matmul(&a, &b, 2, 2, 2), vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_rectangular() {
        // (1x3) @ (3x2)
        let a = vec![1.0, 2.0, 3.0];
        let b = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        assert_eq!(matmul(&a, &b, 1, 3, 2), vec![4.0, 5.0]);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let x = vec![7.0, 8.0];
        assert_eq!(matvec(&a, &x, 3, 2), matmul(&a, &x, 3, 2, 1));
    }

    #[test]
    fn activations() {
        let mut v = vec![-1.0, 0.5];
        relu_inplace(&mut v);
        assert_eq!(v, vec![0.0, 0.5]);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(10.0) > 0.99);
    }

    #[test]
    fn sparsity_counts_zeros() {
        assert_eq!(sparsity(&[0.0, 1.0, 0.0, 2.0]), 0.5);
        assert_eq!(sparsity(&[]), 0.0);
    }

    /// Tiny CSR of [[0.5, 0.2, 0], [0, 0.9, 0]] padded to 3 output rows.
    fn tiny_csr() -> (Vec<u32>, Vec<u16>, Vec<f32>) {
        (vec![0, 2, 3], vec![0, 1, 1], vec![0.5, 0.2, 0.9])
    }

    #[test]
    fn csr_spmm_matches_dense_matmul() {
        let (indptr, indices, weights) = tiny_csr();
        let a_dense = vec![0.5, 0.2, 0.0, 0.0, 0.9, 0.0, 0.0, 0.0, 0.0];
        let x = vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0];
        let want = matmul(&a_dense, &x, 3, 3, 2);
        let (got, macs) = csr_spmm(&indptr, &indices, &weights, &x, 3, 2);
        assert_eq!(got, want);
        assert_eq!(macs, 3 * 2);
        // padded row untouched
        assert_eq!(&got[4..], &[0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "CSR column")]
    fn csr_spmm_rejects_out_of_range_column() {
        // x is 2 rows of f=2 (len 4, so the old `len % f == 0` check
        // passed) but the CSR references column 5.
        let (got, _) = csr_spmm(&[0, 1], &[5], &[1.0], &[1.0, 2.0, 3.0, 4.0], 1, 2);
        std::hint::black_box(got);
    }

    #[test]
    #[should_panic(expected = "indptr tail")]
    fn csr_spmm_rejects_truncated_indptr() {
        // indptr claims 1 nnz but 2 entries exist: the tail check fires
        // before a silent partial traversal.
        let (got, _) = csr_spmm(&[0, 1], &[0, 1], &[1.0, 1.0], &[1.0, 2.0, 3.0, 4.0], 1, 2);
        std::hint::black_box(got);
    }

    #[test]
    fn onehot_gather_selects_weight_rows() {
        // rows: one-hot(2), one-hot(0), all-zero pad
        let h = vec![0.0, 0.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let w = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 3 x 2
        let want = matmul(&h, &w, 3, 3, 2);
        let (got, nnz, macs) = onehot_gather(&h, &w, 2, 3, 3, 2);
        assert_eq!(got, want);
        assert_eq!(got[..2], [5.0, 6.0]);
        assert_eq!(got[2..4], [1.0, 2.0]);
        assert_eq!(nnz, 2);
        assert_eq!(macs, 4);
    }

    #[test]
    fn sparse_row_matmul_matches_dense_and_counts_nonzeros() {
        // 2 real rows + 1 padded, 3 input features, 2 outputs.
        let h = vec![0.5, 0.0, -1.0, 0.0, 2.0, 0.0, 9.0, 9.0, 9.0];
        let w = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let dense_real = matmul(&h[..6], &w, 2, 3, 2);
        let (got, nnz, macs) = sparse_row_matmul(&h, &w, 2, 3, 3, 2);
        assert_eq!(&got[..4], dense_real.as_slice());
        // padded row's garbage input is never read
        assert_eq!(&got[4..], &[0.0, 0.0]);
        assert_eq!(nnz, 3);
        assert_eq!(macs, 6);
    }
}
