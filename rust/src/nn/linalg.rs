//! Small dense f32 linear algebra for the independent rust reference
//! (nn::simgnn) and the simulator's functional model. Row-major, no
//! external BLAS — the matrices here are at most 64x64.

/// out[m,n] = a[m,k] @ b[k,n]  (row-major, accumulate in f32).
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "a shape");
    assert_eq!(b.len(), k * n, "b shape");
    let mut out = vec![0.0f32; m * n];
    // ikj loop order: streams b row-wise, vectorizer-friendly.
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // sparse-friendly: skip zero activations
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
    out
}

/// out[m] = a[m,n] @ x[n]
pub fn matvec(a: &[f32], x: &[f32], m: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), n);
    (0..m)
        .map(|i| {
            a[i * n..(i + 1) * n]
                .iter()
                .zip(x.iter())
                .map(|(&av, &xv)| av * xv)
                .sum()
        })
        .collect()
}

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b.iter()).map(|(&x, &y)| x * y).sum()
}

pub fn relu_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

pub fn tanh_vec(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.tanh();
    }
}

/// Fraction of exact zeros in a slice (sparsity measurement, §3.4).
pub fn sparsity(x: &[f32]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().filter(|&&v| v == 0.0).count() as f64 / x.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let i = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &i, 2, 2, 2), a);
        assert_eq!(matmul(&i, &a, 2, 2, 2), a);
    }

    #[test]
    fn matmul_known() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![5.0, 6.0, 7.0, 8.0];
        assert_eq!(matmul(&a, &b, 2, 2, 2), vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_rectangular() {
        // (1x3) @ (3x2)
        let a = vec![1.0, 2.0, 3.0];
        let b = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        assert_eq!(matmul(&a, &b, 1, 3, 2), vec![4.0, 5.0]);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let x = vec![7.0, 8.0];
        assert_eq!(matvec(&a, &x, 3, 2), matmul(&a, &x, 3, 2, 1));
    }

    #[test]
    fn activations() {
        let mut v = vec![-1.0, 0.5];
        relu_inplace(&mut v);
        assert_eq!(v, vec![0.0, 0.5]);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(10.0) > 0.99);
    }

    #[test]
    fn sparsity_counts_zeros() {
        assert_eq!(sparsity(&[0.0, 1.0, 0.0, 2.0]), 0.5);
        assert_eq!(sparsity(&[]), 0.0);
    }
}
