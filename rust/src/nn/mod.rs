//! Independent rust reference numerics for SimGNN + config/weight loaders.
//! Hot-path kernels dispatch through `kernels` (scalar ↔ vectorized
//! lanes, DESIGN.md S16); `linalg` holds the scalar reference loops.
pub mod config;
pub mod kernels;
pub mod linalg;
pub mod simgnn;
pub mod weights;
