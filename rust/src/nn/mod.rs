//! Independent rust reference numerics for SimGNN + config/weight loaders.
pub mod config;
pub mod linalg;
pub mod simgnn;
pub mod weights;
