//! Independent rust reference implementation of the SimGNN forward pass.
//!
//! This is the third implementation of the same math (after the Pallas
//! kernels and the jnp oracle) and serves three roles:
//!  * golden cross-check against python (tests/golden/simgnn_golden.json);
//!  * the functional model inside the cycle simulator (sim/), which needs
//!    per-stage intermediates and real sparsity counts;
//!  * the measured CPU baseline engine (runtime/native.rs).
//!
//! Hot-path math routes through the `nn::kernels` dispatch layer
//! (scalar ↔ vectorized lanes, DESIGN.md S16), so every engine built on
//! these functions inherits the active kernel path.

use crate::graph::encode::EncodedGraph;

use super::config::ModelConfig;
use super::kernels;
use super::linalg::{matmul, relu_inplace, sigmoid, sparsity};
use super::weights::Weights;

/// Which compute path `gcn_forward` takes. Both produce bit-identical
/// scores (the sparse kernels accumulate in the same order as the dense
/// loops); they differ only in the work touched — see DESIGN.md S13.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SparsePolicy {
    /// Dense padded matmuls over `n_max` (the original CPU baseline).
    Dense,
    /// CSR aggregation + one-hot/nonzero-skipping FT over real rows only
    /// (the serving default, exploiting all three sparsity sources the
    /// paper names: one-hot inputs, post-ReLU zeros, sparse adjacency).
    #[default]
    Csr,
}

impl SparsePolicy {
    /// The stable CLI/report spelling of this policy.
    pub fn as_str(&self) -> &'static str {
        match self {
            SparsePolicy::Dense => "dense",
            SparsePolicy::Csr => "csr",
        }
    }
}

/// Per-stage intermediates of one graph's GCN pass (used by the simulator
/// to drive cycle counts with *real* data sparsity).
#[derive(Debug, Clone)]
pub struct GcnTrace {
    /// Input to each layer (h0, h1, h2), row-major n_max x f_in.
    pub layer_inputs: Vec<Vec<f32>>,
    /// Final node embeddings, n_max x F.
    pub embeddings: Vec<f32>,
    /// Sparsity (fraction of zeros) of each layer input over real rows.
    pub input_sparsity: Vec<f64>,
    /// Input elements the FT is charged for per layer: on the Csr path
    /// exactly the nonzeros `sim::ft::nonzero_stream` yields; on the
    /// Dense path every padded element of the schedule (`n_max * f_in`).
    pub ft_elements: [u64; 3],
    /// Adjacency entries the aggregation is charged for, summed over
    /// layers (CSR nonzeros vs the `3 * n_max²` dense schedule).
    pub agg_elements: u64,
    /// Multiply-accumulates charged across the three FT + aggregation
    /// steps (bias/activation excluded — they are O(n·f), noise here).
    /// Csr counts the work actually executed; Dense counts the full
    /// padded schedule a dense datapath would run — the CPU reference
    /// loop also skips zeros at runtime, so Dense wall-clock is better
    /// than these numbers imply. The ratio mirrors Table 6's saving.
    pub macs: u64,
}

/// Run the 3-layer GCN stage on one encoded graph (sparse serving path;
/// see [`gcn_forward_with`] for the explicit path selector).
pub fn gcn_forward(cfg: &ModelConfig, w: &Weights, g: &EncodedGraph) -> GcnTrace {
    gcn_forward_with(cfg, w, g, SparsePolicy::default())
}

/// Run the 3-layer GCN stage under an explicit [`SparsePolicy`].
pub fn gcn_forward_with(
    cfg: &ModelConfig,
    w: &Weights,
    g: &EncodedGraph,
    policy: SparsePolicy,
) -> GcnTrace {
    let n = cfg.n_max;
    let rows = g.num_nodes;
    // The sparse path iterates rows 0..num_nodes; encode/unpack validate
    // the prefix invariant, this guards direct constructions in tests.
    debug_assert!(
        g.mask[..rows].iter().all(|&m| m != 0.0),
        "real-node mask is not a prefix"
    );
    let mut h = g.h0.clone();
    let mut layer_inputs = Vec::with_capacity(3);
    let mut input_sparsity = Vec::with_capacity(3);
    let mut ft_elements = [0u64; 3];
    let mut agg_elements = 0u64;
    let mut macs = 0u64;
    let dims_in = cfg.feature_dims();
    for layer in 0..3 {
        let f_in = dims_in[layer];
        let f_out = cfg.filters[layer];
        // Sparsity over real rows only (paper counts real-node features).
        input_sparsity.push(sparsity(&h[..rows * f_in]));
        layer_inputs.push(h.clone());
        let mut agg = match policy {
            SparsePolicy::Dense => {
                // Feature Transformation: X = H @ W  (n x f_out)
                let x = matmul(&h, &w.gcn_w[layer], n, f_in, f_out);
                ft_elements[layer] = (n * f_in) as u64;
                macs += (n * f_in * f_out) as u64;
                // Aggregation: A' @ X over the full padded matrix.
                agg_elements += (n * n) as u64;
                macs += (n * n * f_out) as u64;
                matmul(&g.a_norm, &x, n, n, f_out)
            }
            SparsePolicy::Csr => {
                // FT: one-hot row-select at layer 0, nonzero-skipping
                // real-row iteration after ReLU (§3.4's sparsity sources).
                // All three kernels go through the dispatch layer
                // (DESIGN.md S16) so the vectorized path is one switch
                // away from every engine; both paths are bit-identical.
                let (x, nnz, ft_macs) = if layer == 0 {
                    kernels::onehot_gather(&h, &w.gcn_w[layer], rows, n, f_in, f_out)
                } else {
                    kernels::sparse_row_matmul(&h, &w.gcn_w[layer], rows, n, f_in, f_out)
                };
                ft_elements[layer] = nnz;
                macs += ft_macs;
                // Aggregation: nnz-bucketed CSR SpMM over real rows only.
                let (a, agg_macs) =
                    kernels::csr_spmm(&g.csr.indptr, &g.csr.indices, &g.csr.weights, &x, n, f_out);
                agg_elements += g.csr.nnz() as u64;
                macs += agg_macs;
                a
            }
        };
        // Masked bias + activation. The sparse path adds the bias to real
        // rows only (mask is 1 there); padded rows stay exactly zero, as
        // the dense `m * b` product leaves them.
        match policy {
            SparsePolicy::Dense => {
                for i in 0..n {
                    let m = g.mask[i];
                    for j in 0..f_out {
                        agg[i * f_out + j] += m * w.gcn_b[layer][j];
                    }
                }
            }
            SparsePolicy::Csr => {
                for i in 0..rows {
                    for j in 0..f_out {
                        agg[i * f_out + j] += w.gcn_b[layer][j];
                    }
                }
            }
        }
        if cfg.relu_mask[layer] {
            relu_inplace(&mut agg);
        } else if policy == SparsePolicy::Dense {
            for i in 0..n {
                if g.mask[i] == 0.0 {
                    for j in 0..f_out {
                        agg[i * f_out + j] = 0.0;
                    }
                }
            }
        }
        // Csr + no-relu: padded rows were never written, already zero.
        h = agg;
    }
    GcnTrace {
        embeddings: h.clone(),
        layer_inputs,
        input_sparsity,
        ft_elements,
        agg_elements,
        macs,
    }
}

/// Attention pooling (Eq. 3) on node embeddings -> graph embedding (F,).
pub fn attention_pool(cfg: &ModelConfig, w: &Weights, emb: &[f32], mask: &[f32]) -> Vec<f32> {
    let n = cfg.n_max;
    let f = cfg.embed_dim();
    let count: f32 = mask.iter().sum::<f32>().max(1.0);
    let mut mean = vec![0.0f32; f];
    for i in 0..n {
        if mask[i] != 0.0 {
            for j in 0..f {
                mean[j] += emb[i * f + j];
            }
        }
    }
    for v in mean.iter_mut() {
        *v /= count;
    }
    let mut c = kernels::matvec(&w.att_w, &mean, f, f);
    for v in c.iter_mut() {
        *v = v.tanh();
    }
    let mut out = vec![0.0f32; f];
    for i in 0..n {
        if mask[i] == 0.0 {
            continue;
        }
        let row = &emb[i * f..(i + 1) * f];
        let a = sigmoid(kernels::dot(row, &c));
        for j in 0..f {
            out[j] += a * row[j];
        }
    }
    out
}

/// NTN (Eq. 4) -> K similarity slices.
pub fn ntn_forward(cfg: &ModelConfig, w: &Weights, hg1: &[f32], hg2: &[f32]) -> Vec<f32> {
    let f = cfg.embed_dim();
    let k = cfg.ntn_k;
    let mut out = vec![0.0f32; k];
    for slice in 0..k {
        let wk = &w.ntn_w[slice * f * f..(slice + 1) * f * f];
        // hg1^T W_k hg2 — register-blocked on the lanes path (S16).
        let bilinear = kernels::ntn_bilinear(wk, hg1, hg2, f);
        let vk = &w.ntn_v[slice * 2 * f..(slice + 1) * 2 * f];
        let linear = kernels::dot(&vk[..f], hg1) + kernels::dot(&vk[f..], hg2);
        out[slice] = (bilinear + linear + w.ntn_b[slice]).max(0.0);
    }
    out
}

/// FCN scorer -> similarity in (0, 1).
pub fn fcn_forward(cfg: &ModelConfig, w: &Weights, s: &[f32]) -> f32 {
    let mut x = s.to_vec();
    let mut d = cfg.ntn_k;
    for (fw, fb) in w.fc_w.iter().zip(w.fc_b.iter()) {
        let h = fb.len();
        // x (1 x d) @ fw (d x h), through the kernel dispatch layer.
        let mut y = kernels::vec_mat(&x, fw, d, h);
        for (v, &b) in y.iter_mut().zip(fb.iter()) {
            *v += b;
        }
        relu_inplace(&mut y);
        x = y;
        d = h;
    }
    let logit = kernels::dot(&x, &w.out_w) + w.out_b[0];
    sigmoid(logit)
}

/// One graph's share of the pair forward: the GCN trace plus the
/// post-attention graph embedding `hg` (F,). This is the unit the
/// runtime's embedding cache stores — everything per-graph; the NTN+FCN
/// tail ([`pair_score`]) is the only per-pair work left (DESIGN.md S14).
#[derive(Debug, Clone)]
pub struct GraphEmbedding {
    /// GCN per-stage intermediates and work counts.
    pub trace: GcnTrace,
    /// Post-attention graph-level embedding, `embed_dim()` floats.
    pub hg: Vec<f32>,
}

/// Per-graph stage: GCN forward + attention pooling (sparse default).
pub fn embed_graph(cfg: &ModelConfig, w: &Weights, g: &EncodedGraph) -> GraphEmbedding {
    embed_graph_with(cfg, w, g, SparsePolicy::default())
}

/// Per-graph stage under an explicit [`SparsePolicy`].
pub fn embed_graph_with(
    cfg: &ModelConfig,
    w: &Weights,
    g: &EncodedGraph,
    policy: SparsePolicy,
) -> GraphEmbedding {
    let trace = gcn_forward_with(cfg, w, g, policy);
    let hg = attention_pool(cfg, w, &trace.embeddings, &g.mask);
    GraphEmbedding { trace, hg }
}

/// Per-pair tail: NTN similarity slices + FCN scorer on two graph
/// embeddings. Returns `(ntn_out, score)`. Composing
/// [`embed_graph_with`] with this is bit-identical to the fused
/// [`simgnn_forward_with`] — the fused path is implemented on top of
/// exactly these two calls.
pub fn pair_score(cfg: &ModelConfig, w: &Weights, hg1: &[f32], hg2: &[f32]) -> (Vec<f32>, f32) {
    let ntn_out = ntn_forward(cfg, w, hg1, hg2);
    let score = fcn_forward(cfg, w, &ntn_out);
    (ntn_out, score)
}

/// Full per-pair forward with all intermediates exposed.
#[derive(Debug, Clone)]
pub struct PairTrace {
    pub trace1: GcnTrace,
    pub trace2: GcnTrace,
    pub hg1: Vec<f32>,
    pub hg2: Vec<f32>,
    pub ntn_out: Vec<f32>,
    pub score: f32,
}

/// Score one encoded pair on the sparse serving path (the NativeEngine
/// hot path; see [`simgnn_forward_with`] for the explicit selector).
pub fn simgnn_forward(
    cfg: &ModelConfig,
    w: &Weights,
    g1: &EncodedGraph,
    g2: &EncodedGraph,
) -> PairTrace {
    simgnn_forward_with(cfg, w, g1, g2, SparsePolicy::default())
}

/// Score one encoded pair under an explicit [`SparsePolicy`].
///
/// Implemented on the split API (per-graph [`embed_graph_with`] × 2,
/// then the per-pair [`pair_score`] tail), so the fused and split paths
/// cannot drift: they are the same code, hence bit-identical.
pub fn simgnn_forward_with(
    cfg: &ModelConfig,
    w: &Weights,
    g1: &EncodedGraph,
    g2: &EncodedGraph,
    policy: SparsePolicy,
) -> PairTrace {
    let e1 = embed_graph_with(cfg, w, g1, policy);
    let e2 = embed_graph_with(cfg, w, g2, policy);
    let (ntn_out, score) = pair_score(cfg, w, &e1.hg, &e2.hg);
    PairTrace {
        trace1: e1.trace,
        trace2: e2.trace,
        hg1: e1.hg,
        hg2: e2.hg,
        ntn_out,
        score,
    }
}

/// Score only (skips cloning intermediates where possible).
pub fn simgnn_score(
    cfg: &ModelConfig,
    w: &Weights,
    g1: &EncodedGraph,
    g2: &EncodedGraph,
) -> f32 {
    simgnn_forward(cfg, w, g1, g2).score
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::encode::encode;
    use crate::graph::generate::{generate, Family};
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            n_max: 8,
            num_labels: 4,
            filters: [4, 4, 4],
            relu_mask: [true, true, false],
            ntn_k: 4,
            fc_dims: vec![4],
            seed: 0,
        }
    }

    fn const_weights(cfg: &ModelConfig, v: f32) -> Weights {
        let dims_in = cfg.feature_dims();
        let f = cfg.embed_dim();
        let k = cfg.ntn_k;
        let mut fc_w = Vec::new();
        let mut fc_b = Vec::new();
        let mut d = k;
        for &h in &cfg.fc_dims {
            fc_w.push(vec![v; d * h]);
            fc_b.push(vec![0.0; h]);
            d = h;
        }
        Weights {
            gcn_w: [
                vec![v; dims_in[0] * cfg.filters[0]],
                vec![v; dims_in[1] * cfg.filters[1]],
                vec![v; dims_in[2] * cfg.filters[2]],
            ],
            gcn_b: [
                vec![0.0; cfg.filters[0]],
                vec![0.0; cfg.filters[1]],
                vec![0.0; cfg.filters[2]],
            ],
            att_w: vec![v; f * f],
            ntn_w: vec![v; k * f * f],
            ntn_v: vec![v; k * 2 * f],
            ntn_b: vec![0.0; k],
            fc_w,
            fc_b,
            out_w: vec![v; d],
            out_b: vec![0.0],
        }
    }

    #[test]
    fn padded_rows_stay_zero() {
        let cfg = tiny_cfg();
        let w = const_weights(&cfg, 0.1);
        let mut rng = Rng::new(51);
        let g = generate(&mut rng, Family::ErdosRenyi { n: 5, p_millis: 300 }, 8, 4);
        let e = encode(&g, cfg.n_max, cfg.num_labels).unwrap();
        let t = gcn_forward(&cfg, &w, &e);
        let f = cfg.embed_dim();
        for i in g.num_nodes()..cfg.n_max {
            for j in 0..f {
                assert_eq!(t.embeddings[i * f + j], 0.0, "pad row {i} leaked");
            }
        }
    }

    #[test]
    fn symmetric_pair_is_symmetric_score() {
        // NTN is not symmetric in general, but identical graphs must give
        // identical embeddings, so score(g,g) is deterministic and the
        // bilinear term is symmetric under hg1 == hg2.
        let cfg = tiny_cfg();
        let w = const_weights(&cfg, 0.05);
        let mut rng = Rng::new(52);
        let g = generate(&mut rng, Family::ErdosRenyi { n: 6, p_millis: 300 }, 8, 4);
        let e = encode(&g, cfg.n_max, cfg.num_labels).unwrap();
        let s1 = simgnn_score(&cfg, &w, &e, &e);
        let s2 = simgnn_score(&cfg, &w, &e, &e);
        assert_eq!(s1, s2);
        assert!(s1 > 0.0 && s1 < 1.0);
    }

    #[test]
    fn score_in_unit_interval_random_weights() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(53);
        let mut w = const_weights(&cfg, 0.0);
        let fill = |v: &mut Vec<f32>, rng: &mut Rng| {
            for x in v.iter_mut() {
                *x = (rng.f32() - 0.5) * 0.8;
            }
        };
        for i in 0..3 {
            fill(&mut w.gcn_w[i], &mut rng);
        }
        fill(&mut w.att_w, &mut rng);
        fill(&mut w.ntn_w, &mut rng);
        fill(&mut w.ntn_v, &mut rng);
        for fw in w.fc_w.iter_mut() {
            fill(fw, &mut rng);
        }
        fill(&mut w.out_w, &mut rng);
        for _ in 0..10 {
            let g1 = generate(&mut rng, Family::ErdosRenyi { n: 6, p_millis: 250 }, 8, 4);
            let g2 = generate(&mut rng, Family::ErdosRenyi { n: 7, p_millis: 250 }, 8, 4);
            let e1 = encode(&g1, cfg.n_max, cfg.num_labels).unwrap();
            let e2 = encode(&g2, cfg.n_max, cfg.num_labels).unwrap();
            let s = simgnn_score(&cfg, &w, &e1, &e2);
            assert!(s > 0.0 && s < 1.0, "score {s} out of range");
        }
    }

    #[test]
    fn dense_and_csr_paths_agree_bit_for_bit() {
        // The sparse kernels accumulate in the dense loops' order, so the
        // two policies must agree exactly — not just within tolerance.
        let cfg = tiny_cfg();
        let w = const_weights(&cfg, 0.07);
        let mut rng = Rng::new(55);
        for i in 0..20 {
            let n = 2 + (i % 7);
            let g = generate(&mut rng, Family::ErdosRenyi { n, p_millis: 350 }, 8, 4);
            let e = encode(&g, cfg.n_max, cfg.num_labels).unwrap();
            let d = gcn_forward_with(&cfg, &w, &e, SparsePolicy::Dense);
            let s = gcn_forward_with(&cfg, &w, &e, SparsePolicy::Csr);
            assert_eq!(d.embeddings, s.embeddings, "graph {i} embeddings diverged");
            assert_eq!(d.layer_inputs, s.layer_inputs, "graph {i} traces diverged");
        }
    }

    #[test]
    fn sparse_path_does_less_work() {
        let cfg = tiny_cfg();
        let w = const_weights(&cfg, 0.1);
        let mut rng = Rng::new(56);
        let g = generate(&mut rng, Family::ErdosRenyi { n: 5, p_millis: 300 }, 8, 4);
        let e = encode(&g, cfg.n_max, cfg.num_labels).unwrap();
        let d = gcn_forward_with(&cfg, &w, &e, SparsePolicy::Dense);
        let s = gcn_forward_with(&cfg, &w, &e, SparsePolicy::Csr);
        // Layer 0: one element per real node vs every padded slot.
        assert_eq!(s.ft_elements[0], e.num_nodes as u64);
        assert_eq!(d.ft_elements[0], (cfg.n_max * cfg.num_labels) as u64);
        // Aggregation: CSR nonzeros per layer vs n_max² per layer.
        assert_eq!(s.agg_elements, 3 * e.csr.nnz() as u64);
        assert_eq!(d.agg_elements, 3 * (cfg.n_max * cfg.n_max) as u64);
        assert!(s.macs < d.macs, "sparse {} !< dense {}", s.macs, d.macs);
    }

    #[test]
    fn csr_ft_elements_match_sim_nonzero_stream() {
        // The sparse FT consumes exactly the elements the cycle
        // simulator's pruning-unit model dispatches for the same trace.
        use crate::sim::ft::nonzero_stream;
        let cfg = tiny_cfg();
        let w = const_weights(&cfg, 0.09);
        let mut rng = Rng::new(57);
        let dims_in = cfg.feature_dims();
        for _ in 0..10 {
            let g = generate(&mut rng, Family::ErdosRenyi { n: 6, p_millis: 300 }, 8, 4);
            let e = encode(&g, cfg.n_max, cfg.num_labels).unwrap();
            let t = gcn_forward_with(&cfg, &w, &e, SparsePolicy::Csr);
            for layer in 0..3 {
                let stream = nonzero_stream(&t.layer_inputs[layer], e.num_nodes, dims_in[layer]);
                assert_eq!(
                    t.ft_elements[layer],
                    stream.len() as u64,
                    "layer {layer} FT element count vs nonzero stream"
                );
            }
        }
    }

    #[test]
    fn split_api_matches_fused_forward_bit_for_bit() {
        // embed_graph + pair_score IS the fused forward (one is built on
        // the other), but pin it with an explicit cross-check so a future
        // divergence of the two paths cannot slip by.
        let cfg = tiny_cfg();
        let w = const_weights(&cfg, 0.06);
        let mut rng = Rng::new(58);
        for policy in [SparsePolicy::Dense, SparsePolicy::Csr] {
            for _ in 0..5 {
                let g1 = generate(&mut rng, Family::ErdosRenyi { n: 5, p_millis: 300 }, 8, 4);
                let g2 = generate(&mut rng, Family::ErdosRenyi { n: 7, p_millis: 300 }, 8, 4);
                let e1 = encode(&g1, cfg.n_max, cfg.num_labels).unwrap();
                let e2 = encode(&g2, cfg.n_max, cfg.num_labels).unwrap();
                let fused = simgnn_forward_with(&cfg, &w, &e1, &e2, policy);
                let m1 = embed_graph_with(&cfg, &w, &e1, policy);
                let m2 = embed_graph_with(&cfg, &w, &e2, policy);
                let (ntn, score) = pair_score(&cfg, &w, &m1.hg, &m2.hg);
                assert_eq!(fused.hg1, m1.hg);
                assert_eq!(fused.hg2, m2.hg);
                assert_eq!(fused.ntn_out, ntn);
                assert_eq!(fused.score, score);
            }
        }
    }

    #[test]
    fn one_hot_input_sparsity_is_high() {
        let cfg = tiny_cfg();
        let w = const_weights(&cfg, 0.1);
        let mut rng = Rng::new(54);
        let g = generate(&mut rng, Family::ErdosRenyi { n: 8, p_millis: 300 }, 8, 4);
        let e = encode(&g, cfg.n_max, cfg.num_labels).unwrap();
        let t = gcn_forward(&cfg, &w, &e);
        // one-hot rows: (num_labels-1)/num_labels zeros
        assert!(t.input_sparsity[0] >= 0.7, "{}", t.input_sparsity[0]);
    }
}
