//! Independent rust reference implementation of the SimGNN forward pass.
//!
//! This is the third implementation of the same math (after the Pallas
//! kernels and the jnp oracle) and serves three roles:
//!  * golden cross-check against python (tests/golden/simgnn_golden.json);
//!  * the functional model inside the cycle simulator (sim/), which needs
//!    per-stage intermediates and real sparsity counts;
//!  * the measured CPU baseline engine (runtime/native.rs).

use crate::graph::encode::EncodedGraph;

use super::config::ModelConfig;
use super::linalg::{dot, matmul, matvec, relu_inplace, sigmoid, sparsity};
use super::weights::Weights;

/// Per-stage intermediates of one graph's GCN pass (used by the simulator
/// to drive cycle counts with *real* data sparsity).
#[derive(Debug, Clone)]
pub struct GcnTrace {
    /// Input to each layer (h0, h1, h2), row-major n_max x f_in.
    pub layer_inputs: Vec<Vec<f32>>,
    /// Final node embeddings, n_max x F.
    pub embeddings: Vec<f32>,
    /// Sparsity (fraction of zeros) of each layer input over real rows.
    pub input_sparsity: Vec<f64>,
}

/// Run the 3-layer GCN stage on one encoded graph.
pub fn gcn_forward(cfg: &ModelConfig, w: &Weights, g: &EncodedGraph) -> GcnTrace {
    let n = cfg.n_max;
    let mut h = g.h0.clone();
    let mut layer_inputs = Vec::with_capacity(3);
    let mut input_sparsity = Vec::with_capacity(3);
    let dims_in = cfg.feature_dims();
    for layer in 0..3 {
        let f_in = dims_in[layer];
        let f_out = cfg.filters[layer];
        // Sparsity over real rows only (paper counts real-node features).
        let real_rows = g.num_nodes;
        input_sparsity.push(sparsity(&h[..real_rows * f_in]));
        layer_inputs.push(h.clone());
        // Feature Transformation: X = H @ W  (n x f_out)
        let x = matmul(&h, &w.gcn_w[layer], n, f_in, f_out);
        // Aggregation: A' @ X
        let mut agg = matmul(&g.a_norm, &x, n, n, f_out);
        // Masked bias + activation
        for i in 0..n {
            let m = g.mask[i];
            for j in 0..f_out {
                agg[i * f_out + j] += m * w.gcn_b[layer][j];
            }
        }
        if cfg.relu_mask[layer] {
            relu_inplace(&mut agg);
        } else {
            for i in 0..n {
                if g.mask[i] == 0.0 {
                    for j in 0..f_out {
                        agg[i * f_out + j] = 0.0;
                    }
                }
            }
        }
        h = agg;
    }
    GcnTrace {
        embeddings: h.clone(),
        layer_inputs,
        input_sparsity,
    }
}

/// Attention pooling (Eq. 3) on node embeddings -> graph embedding (F,).
pub fn attention_pool(cfg: &ModelConfig, w: &Weights, emb: &[f32], mask: &[f32]) -> Vec<f32> {
    let n = cfg.n_max;
    let f = cfg.embed_dim();
    let count: f32 = mask.iter().sum::<f32>().max(1.0);
    let mut mean = vec![0.0f32; f];
    for i in 0..n {
        if mask[i] != 0.0 {
            for j in 0..f {
                mean[j] += emb[i * f + j];
            }
        }
    }
    for v in mean.iter_mut() {
        *v /= count;
    }
    let mut c = matvec(&w.att_w, &mean, f, f);
    for v in c.iter_mut() {
        *v = v.tanh();
    }
    let mut out = vec![0.0f32; f];
    for i in 0..n {
        if mask[i] == 0.0 {
            continue;
        }
        let row = &emb[i * f..(i + 1) * f];
        let a = sigmoid(dot(row, &c));
        for j in 0..f {
            out[j] += a * row[j];
        }
    }
    out
}

/// NTN (Eq. 4) -> K similarity slices.
pub fn ntn_forward(cfg: &ModelConfig, w: &Weights, hg1: &[f32], hg2: &[f32]) -> Vec<f32> {
    let f = cfg.embed_dim();
    let k = cfg.ntn_k;
    let mut out = vec![0.0f32; k];
    for slice in 0..k {
        let wk = &w.ntn_w[slice * f * f..(slice + 1) * f * f];
        // hg1^T W_k hg2
        let wh2 = matvec(wk, hg2, f, f);
        let bilinear = dot(hg1, &wh2);
        let vk = &w.ntn_v[slice * 2 * f..(slice + 1) * 2 * f];
        let linear = dot(&vk[..f], hg1) + dot(&vk[f..], hg2);
        out[slice] = (bilinear + linear + w.ntn_b[slice]).max(0.0);
    }
    out
}

/// FCN scorer -> similarity in (0, 1).
pub fn fcn_forward(cfg: &ModelConfig, w: &Weights, s: &[f32]) -> f32 {
    let mut x = s.to_vec();
    let mut d = cfg.ntn_k;
    for (fw, fb) in w.fc_w.iter().zip(w.fc_b.iter()) {
        let h = fb.len();
        // x (1 x d) @ fw (d x h)
        let mut y = matmul(&x, fw, 1, d, h);
        for (v, &b) in y.iter_mut().zip(fb.iter()) {
            *v += b;
        }
        relu_inplace(&mut y);
        x = y;
        d = h;
    }
    let logit = dot(&x, &w.out_w) + w.out_b[0];
    sigmoid(logit)
}

/// Full per-pair forward with all intermediates exposed.
#[derive(Debug, Clone)]
pub struct PairTrace {
    pub trace1: GcnTrace,
    pub trace2: GcnTrace,
    pub hg1: Vec<f32>,
    pub hg2: Vec<f32>,
    pub ntn_out: Vec<f32>,
    pub score: f32,
}

/// Score one encoded pair (the NativeEngine hot path).
pub fn simgnn_forward(
    cfg: &ModelConfig,
    w: &Weights,
    g1: &EncodedGraph,
    g2: &EncodedGraph,
) -> PairTrace {
    let trace1 = gcn_forward(cfg, w, g1);
    let trace2 = gcn_forward(cfg, w, g2);
    let hg1 = attention_pool(cfg, w, &trace1.embeddings, &g1.mask);
    let hg2 = attention_pool(cfg, w, &trace2.embeddings, &g2.mask);
    let ntn_out = ntn_forward(cfg, w, &hg1, &hg2);
    let score = fcn_forward(cfg, w, &ntn_out);
    PairTrace {
        trace1,
        trace2,
        hg1,
        hg2,
        ntn_out,
        score,
    }
}

/// Score only (skips cloning intermediates where possible).
pub fn simgnn_score(
    cfg: &ModelConfig,
    w: &Weights,
    g1: &EncodedGraph,
    g2: &EncodedGraph,
) -> f32 {
    simgnn_forward(cfg, w, g1, g2).score
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::encode::encode;
    use crate::graph::generate::{generate, Family};
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            n_max: 8,
            num_labels: 4,
            filters: [4, 4, 4],
            relu_mask: [true, true, false],
            ntn_k: 4,
            fc_dims: vec![4],
            seed: 0,
        }
    }

    fn const_weights(cfg: &ModelConfig, v: f32) -> Weights {
        let dims_in = cfg.feature_dims();
        let f = cfg.embed_dim();
        let k = cfg.ntn_k;
        let mut fc_w = Vec::new();
        let mut fc_b = Vec::new();
        let mut d = k;
        for &h in &cfg.fc_dims {
            fc_w.push(vec![v; d * h]);
            fc_b.push(vec![0.0; h]);
            d = h;
        }
        Weights {
            gcn_w: [
                vec![v; dims_in[0] * cfg.filters[0]],
                vec![v; dims_in[1] * cfg.filters[1]],
                vec![v; dims_in[2] * cfg.filters[2]],
            ],
            gcn_b: [
                vec![0.0; cfg.filters[0]],
                vec![0.0; cfg.filters[1]],
                vec![0.0; cfg.filters[2]],
            ],
            att_w: vec![v; f * f],
            ntn_w: vec![v; k * f * f],
            ntn_v: vec![v; k * 2 * f],
            ntn_b: vec![0.0; k],
            fc_w,
            fc_b,
            out_w: vec![v; d],
            out_b: vec![0.0],
        }
    }

    #[test]
    fn padded_rows_stay_zero() {
        let cfg = tiny_cfg();
        let w = const_weights(&cfg, 0.1);
        let mut rng = Rng::new(51);
        let g = generate(&mut rng, Family::ErdosRenyi { n: 5, p_millis: 300 }, 8, 4);
        let e = encode(&g, cfg.n_max, cfg.num_labels).unwrap();
        let t = gcn_forward(&cfg, &w, &e);
        let f = cfg.embed_dim();
        for i in g.num_nodes()..cfg.n_max {
            for j in 0..f {
                assert_eq!(t.embeddings[i * f + j], 0.0, "pad row {i} leaked");
            }
        }
    }

    #[test]
    fn symmetric_pair_is_symmetric_score() {
        // NTN is not symmetric in general, but identical graphs must give
        // identical embeddings, so score(g,g) is deterministic and the
        // bilinear term is symmetric under hg1 == hg2.
        let cfg = tiny_cfg();
        let w = const_weights(&cfg, 0.05);
        let mut rng = Rng::new(52);
        let g = generate(&mut rng, Family::ErdosRenyi { n: 6, p_millis: 300 }, 8, 4);
        let e = encode(&g, cfg.n_max, cfg.num_labels).unwrap();
        let s1 = simgnn_score(&cfg, &w, &e, &e);
        let s2 = simgnn_score(&cfg, &w, &e, &e);
        assert_eq!(s1, s2);
        assert!(s1 > 0.0 && s1 < 1.0);
    }

    #[test]
    fn score_in_unit_interval_random_weights() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(53);
        let mut w = const_weights(&cfg, 0.0);
        let fill = |v: &mut Vec<f32>, rng: &mut Rng| {
            for x in v.iter_mut() {
                *x = (rng.f32() - 0.5) * 0.8;
            }
        };
        for i in 0..3 {
            fill(&mut w.gcn_w[i], &mut rng);
        }
        fill(&mut w.att_w, &mut rng);
        fill(&mut w.ntn_w, &mut rng);
        fill(&mut w.ntn_v, &mut rng);
        for fw in w.fc_w.iter_mut() {
            fill(fw, &mut rng);
        }
        fill(&mut w.out_w, &mut rng);
        for _ in 0..10 {
            let g1 = generate(&mut rng, Family::ErdosRenyi { n: 6, p_millis: 250 }, 8, 4);
            let g2 = generate(&mut rng, Family::ErdosRenyi { n: 7, p_millis: 250 }, 8, 4);
            let e1 = encode(&g1, cfg.n_max, cfg.num_labels).unwrap();
            let e2 = encode(&g2, cfg.n_max, cfg.num_labels).unwrap();
            let s = simgnn_score(&cfg, &w, &e1, &e2);
            assert!(s > 0.0 && s < 1.0, "score {s} out of range");
        }
    }

    #[test]
    fn one_hot_input_sparsity_is_high() {
        let cfg = tiny_cfg();
        let w = const_weights(&cfg, 0.1);
        let mut rng = Rng::new(54);
        let g = generate(&mut rng, Family::ErdosRenyi { n: 8, p_millis: 300 }, 8, 4);
        let e = encode(&g, cfg.n_max, cfg.num_labels).unwrap();
        let t = gcn_forward(&cfg, &w, &e);
        // one-hot rows: (num_labels-1)/num_labels zeros
        assert!(t.input_sparsity[0] >= 0.7, "{}", t.input_sparsity[0]);
    }
}
