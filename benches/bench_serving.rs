//! Replay-driven serving benchmark (ISSUE 9 / DESIGN.md S19): record a
//! 200-query serving workload as a `spa-gcn-trace-v1` trace, replay it
//! twice (asserting byte-identical outcome dumps — the determinism
//! gate), and emit a `bench-serving-v1` snapshot to `bench.json` for
//! the CI perf trajectory. Since ISSUE 10 the bench runs two legs —
//! exact and budgeted-cascade — and the snapshot comes from the
//! cascade replay, so its `cascade` section carries a measured prune
//! rate. The committed `BENCH_10.json` is the estimated-analytic
//! placeholder this bench overwrites with measured numbers; validate
//! either with `spa-gcn bench-check FILE`.
//!
//!     cargo bench --bench bench_serving
//!
//! Needs `artifacts/` (run `make artifacts`); skips itself otherwise,
//! matching the repo's artifact-gated test convention.

use std::path::{Path, PathBuf};

use spa_gcn::coordinator::corpus::Corpus;
use spa_gcn::coordinator::server::{run_replay, serve_workload, ServeConfig};
use spa_gcn::coordinator::trace::{bench_snapshot, check_bench, Trace};
use spa_gcn::graph::generate::{generate, Family};
use spa_gcn::graph::Graph;
use spa_gcn::nn::config::ModelConfig;
use spa_gcn::runtime::EngineKind;
use spa_gcn::util::rng::Rng;

/// The scatter stage must read per-shard unique counts as plan fields,
/// never hash candidates per query (ISSUE 10): `shard_plan` does its
/// one linear pass at plan time over the `prev_same` links built at
/// corpus construction, and the plan's counts must agree with the
/// membership-based definition on a duplicate-heavy corpus.
fn assert_scatter_reads_precomputed_uniques() -> anyhow::Result<()> {
    let cfg = ModelConfig::default();
    let mut rng = Rng::new(1010);
    let mut entries: Vec<(u64, Graph)> = (0..48u64)
        .map(|i| (i, generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels)))
        .collect();
    // Duplicate content under fresh ids, scattered across shard
    // boundaries — the case per-query hashing used to pay for.
    for d in 0..16u64 {
        entries.push((48 + d, entries[(d as usize) * 3].1.clone()));
    }
    let corpus = Corpus::build("bench-plan", &entries, cfg.n_max, cfg.num_labels)
        .map_err(|e| anyhow::anyhow!("building plan corpus: {e}"))?;
    for lanes in [1usize, 2, 3, 4, 7] {
        let plan = corpus.shard_plan(lanes);
        anyhow::ensure!(
            plan.shards.len() == plan.uniques.len(),
            "plan uniques must be parallel to its shards"
        );
        for (shard, &precomputed) in plan.shards.iter().zip(&plan.uniques) {
            anyhow::ensure!(
                precomputed == corpus.unique_in(*shard),
                "lanes={lanes}: precomputed unique count diverged for {shard:?}"
            );
        }
        // A single shard sees every distinct fingerprint exactly once.
        if lanes == 1 {
            anyhow::ensure!(plan.uniques[0] == corpus.unique_graphs());
        }
    }
    println!("scatter plan: per-shard unique counts precomputed, no per-query hashing");
    Ok(())
}

/// Record `cfg`'s workload, replay it twice, and hand back the first
/// replay's outcome (the byte-identical dump pair is the determinism
/// gate both legs share).
fn record_and_replay(
    label: &str,
    cfg: &ServeConfig,
    trace_path: &PathBuf,
) -> anyhow::Result<(spa_gcn::coordinator::metrics::Metrics, f64)> {
    println!("== record ({label}): {}-query workload -> {} ==", cfg.queries, trace_path.display());
    let table = serve_workload(cfg)?;
    println!("{}", table.render());

    let trace = Trace::read(trace_path)
        .map_err(|e| anyhow::anyhow!("reading recorded trace: {e}"))?;
    println!("== replay x2 ({label}) : determinism gate ==");
    let replay_cfg = ServeConfig { record: None, ..cfg.clone() };
    let (metrics, wall_s, dump) = run_replay(&replay_cfg, &trace, None)?;
    let (_, _, dump2) = run_replay(&replay_cfg, &trace, None)?;
    anyhow::ensure!(
        dump == dump2,
        "replay determinism violated ({label}): two replays of {} produced different dumps",
        trace_path.display()
    );
    println!("replayed {} entries twice, dumps byte-identical", trace.len());
    let _ = std::fs::remove_file(trace_path);
    Ok((metrics, wall_s))
}

fn main() -> anyhow::Result<()> {
    assert_scatter_reads_precomputed_uniques()?;
    if !Path::new("artifacts").is_dir() {
        println!("bench_serving: artifacts/ not found (run `make artifacts`); skipping");
        return Ok(());
    }
    let tmp = std::env::temp_dir();
    let pid = std::process::id();

    // Leg 1 — exact: one-vs-many corpus search, the shape the paper's
    // serving argument is about (many small graphs, §5.4.3).
    let exact_trace = tmp.join(format!("spa-gcn-bench-serving-{pid}.trace.jsonl"));
    let exact_cfg = ServeConfig {
        engines: vec![EngineKind::Native],
        queries: 200,
        corpus_size: 64,
        topk: 10,
        seed: 77,
        record: Some(exact_trace.clone()),
        ..ServeConfig::default()
    };
    let (exact_metrics, _) = record_and_replay("exact", &exact_cfg, &exact_trace)?;
    println!(
        "{}",
        exact_metrics.render_table("bench_serving: exact replayed workload").render()
    );

    // Leg 2 — budgeted cascade: same workload shape with the coarse
    // stage pruning each query to a quarter of the corpus. Its replay
    // feeds the snapshot, so the cascade prune-rate section is measured.
    let cascade_trace = tmp.join(format!("spa-gcn-bench-cascade-{pid}.trace.jsonl"));
    let cascade_cfg = ServeConfig {
        budget: 16,
        record: Some(cascade_trace.clone()),
        ..exact_cfg
    };
    let (metrics, wall_s) = record_and_replay("cascade", &cascade_cfg, &cascade_trace)?;
    let table = metrics.render_table("bench_serving: cascade replayed workload");
    anyhow::ensure!(
        table.get("cascade queries").is_some(),
        "budgeted replay must report cascade rows"
    );
    println!("{}", table.render());

    let snap = bench_snapshot(&metrics, wall_s, 10, "measured: benches/bench_serving.rs");
    check_bench(&snap).map_err(|e| anyhow::anyhow!("snapshot fails its own schema: {e}"))?;
    std::fs::write("bench.json", snap.to_string() + "\n")?;
    println!("wrote bench.json (cascade leg, budget={})", cascade_cfg.budget);
    Ok(())
}
