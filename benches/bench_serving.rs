//! Replay-driven serving benchmark (ISSUE 9 / DESIGN.md S19): record a
//! 200-query serving workload as a `spa-gcn-trace-v1` trace, replay it
//! twice (asserting byte-identical outcome dumps — the determinism
//! gate), and emit a `bench-serving-v1` snapshot to `bench.json` for
//! the CI perf trajectory. The committed `BENCH_9.json` is the
//! estimated-analytic placeholder this bench overwrites with measured
//! numbers; validate either with `spa-gcn bench-check FILE`.
//!
//!     cargo bench --bench bench_serving
//!
//! Needs `artifacts/` (run `make artifacts`); skips itself otherwise,
//! matching the repo's artifact-gated test convention.

use std::path::Path;

use spa_gcn::coordinator::server::{run_replay, serve_workload, ServeConfig};
use spa_gcn::coordinator::trace::{bench_snapshot, check_bench, Trace};
use spa_gcn::runtime::EngineKind;

fn main() -> anyhow::Result<()> {
    if !Path::new("artifacts").is_dir() {
        println!("bench_serving: artifacts/ not found (run `make artifacts`); skipping");
        return Ok(());
    }
    let trace_path = std::env::temp_dir()
        .join(format!("spa-gcn-bench-serving-{}.trace.jsonl", std::process::id()));

    // The recorded workload: one-vs-many corpus search, the shape the
    // paper's serving argument is about (many small graphs, §5.4.3).
    let cfg = ServeConfig {
        engines: vec![EngineKind::Native],
        queries: 200,
        corpus_size: 64,
        topk: 10,
        seed: 77,
        record: Some(trace_path.clone()),
        ..ServeConfig::default()
    };
    println!("== record: 200-query serving workload -> {} ==", trace_path.display());
    let table = serve_workload(&cfg)?;
    println!("{}", table.render());

    let trace = Trace::read(&trace_path)
        .map_err(|e| anyhow::anyhow!("reading recorded trace: {e}"))?;
    println!("== replay x2 (flood) : determinism gate + snapshot ==");
    let replay_cfg = ServeConfig { record: None, ..cfg };
    let (metrics, wall_s, dump) = run_replay(&replay_cfg, &trace, None)?;
    let (_, _, dump2) = run_replay(&replay_cfg, &trace, None)?;
    anyhow::ensure!(
        dump == dump2,
        "replay determinism violated: two replays of {} produced different outcome dumps",
        trace_path.display()
    );

    let snap = bench_snapshot(&metrics, wall_s, 9, "measured: benches/bench_serving.rs");
    check_bench(&snap).map_err(|e| anyhow::anyhow!("snapshot fails its own schema: {e}"))?;
    std::fs::write("bench.json", snap.to_string() + "\n")?;
    let _ = std::fs::remove_file(&trace_path);

    println!(
        "replayed {} entries twice, dumps byte-identical; wrote bench.json",
        trace.len()
    );
    println!(
        "{}",
        metrics.render_table("bench_serving: replayed 200-query workload").render()
    );
    Ok(())
}
