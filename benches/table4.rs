//! Bench: regenerate paper Table 4 (GCN architecture ablation on U280)
//! on the full-size workload and time the simulator itself.
//!
//!     cargo bench --bench table4
use spa_gcn::report::tables::{table4, Context};
use spa_gcn::util::bench::time_once;

fn main() -> anyhow::Result<()> {
    let ctx = Context::load(std::path::Path::new("artifacts"))?;
    let (t, secs) = time_once("table4 (400 queries)", || table4(&ctx, 400));
    println!("\n{}", t.render());
    println!("simulator throughput: {:.0} simulated queries/s (3 variants x 400 queries)", 3.0 * 400.0 / secs);
    Ok(())
}
