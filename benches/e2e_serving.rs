//! End-to-end serving benchmark: the full L3 stack (router -> batcher ->
//! engine) under different engines, batch limits and worker counts.
//! This is the measured companion to Fig. 11 / §5.4.3 on this machine.
//!
//!     cargo bench --bench e2e_serving

use spa_gcn::coordinator::server::{serve_workload, ServeConfig};
use spa_gcn::util::bench::time_once;

fn run(engine: &str, queries: usize, workers: usize, batch_max: usize) -> anyhow::Result<()> {
    let cfg = ServeConfig {
        artifacts_dir: "artifacts".into(),
        engine: engine.into(),
        queries,
        workers,
        batch_max,
        batch_timeout_us: 200,
        seed: 77,
    };
    let label = format!("serve {engine} q={queries} w={workers} b={batch_max}");
    let (t, _) = time_once(&label, || serve_workload(&cfg).unwrap());
    // rows: 0 scored, 3 throughput, 5 p50, 7 p99, 8 mean batch
    println!(
        "    -> scored {}  throughput {} q/s  p50 {} ms  p99 {} ms  mean batch {}\n",
        t.rows[0][1], t.rows[3][1], t.rows[5][1], t.rows[7][1], t.rows[8][1]
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("== engine comparison (measured on this machine) ==");
    for engine in ["native", "xla", "xla-fused"] {
        run(engine, 2000, 1, 64)?;
    }

    println!("== batching sweep on the PJRT engine (real Fig. 11) ==");
    for b in [1usize, 4, 16, 64] {
        run("xla", 1000, 1, b)?;
    }

    println!("== worker scaling (native engine; 2-core machine) ==");
    for w in [1usize, 2] {
        run("native", 2000, w, 64)?;
    }
    Ok(())
}
