//! End-to-end serving benchmark: the full L3 staged pipeline (admission
//! -> batcher -> encoder -> executor -> responder) under different
//! engines, batch limits, worker counts and pipeline depths. This is the
//! measured companion to Fig. 11 / §5.4.3 on this machine, plus the
//! host-side overlap experiment: pipelined (encode of batch k+1
//! concurrent with execute of batch k) vs the fused sequential baseline.
//!
//!     cargo bench --bench e2e_serving

use spa_gcn::coordinator::server::{serve_workload, ServeConfig};
use spa_gcn::runtime::EngineKind;
use spa_gcn::util::bench::time_once;

/// Run one serve config and print the headline numbers plus the
/// per-stage latency split; returns the offered throughput (query/s).
fn run(
    engines: &[EngineKind],
    queries: usize,
    workers: usize,
    batch_max: usize,
    depth: usize,
) -> anyhow::Result<f64> {
    let label_engines = engines
        .iter()
        .map(EngineKind::as_str)
        .collect::<Vec<_>>()
        .join(",");
    let cfg = ServeConfig {
        engines: engines.to_vec(),
        queries,
        workers,
        batch_max,
        batch_timeout_us: 200,
        seed: 77,
        pipeline_depth: depth,
        ..ServeConfig::default()
    };
    let label = format!("serve {label_engines} q={queries} w={workers} b={batch_max} d={depth}");
    let (t, _) = time_once(&label, || serve_workload(&cfg).unwrap());
    let g = |k: &str| t.get(k).unwrap_or("-").to_string();
    println!(
        "    -> scored {}  throughput {} q/s  p50 {} ms  p99 {} ms  mean batch {}",
        g("queries scored"),
        g("throughput (query/s)"),
        g("latency p50 (ms)"),
        g("latency p99 (ms)"),
        g("mean batch size"),
    );
    println!(
        "       stage split: queue {} ms  encode {} ms  execute {} ms",
        g("queue wait mean (ms)"),
        g("encode mean (ms)"),
        g("execute mean (ms)"),
    );
    // MAC/element work rows are keyed per engine name (so a mixed
    // native,native-dense run keeps the two policies apart).
    for row in &t.rows {
        if row[0].ends_with(" macs mean")
            || row[0].ends_with(" ft elements mean")
            || row[0].ends_with(" agg elements mean")
        {
            println!("       {}: {}", row[0], row[1]);
        }
    }
    println!();
    let tput = t
        .get("offered throughput (query/s)")
        .ok_or_else(|| anyhow::anyhow!("serve table missing offered-throughput row"))?;
    Ok(tput.parse()?)
}

fn main() -> anyhow::Result<()> {
    println!("== engine comparison (measured on this machine) ==");
    for kind in [EngineKind::Native, EngineKind::Xla, EngineKind::XlaFused] {
        run(&[kind], 2000, 1, 64, 2)?;
    }

    println!("== batching sweep on the PJRT engine (real Fig. 11) ==");
    for b in [1usize, 4, 16, 64] {
        run(&[EngineKind::Xla], 1000, 1, b, 2)?;
    }

    println!("== worker scaling (native engine; 2-core machine) ==");
    for w in [1usize, 2] {
        run(&[EngineKind::Native], 2000, w, 64, 2)?;
    }

    println!("== heterogeneous lanes: native + sim in one pipeline ==");
    run(&[EngineKind::Native, EngineKind::Sim], 1000, 2, 64, 2)?;

    println!("== native scoring path: dense vs sparse (CSR + one-hot FT) ==");
    // Same numerics, two compute paths: the MAC/element rows quantify the
    // skipped work (Table 6's sparsity saving, measured in software) and
    // the throughput ratio is what that saving buys on this machine.
    let dense_qps = run(&[EngineKind::NativeDense], 2000, 1, 64, 2)?;
    let sparse_qps = run(&[EngineKind::Native], 2000, 1, 64, 2)?;
    println!(
        "sparse-path speedup: {:.2}x (sparse {sparse_qps:.0} q/s vs dense {dense_qps:.0} q/s)\n",
        if dense_qps > 0.0 {
            sparse_qps / dense_qps
        } else {
            0.0
        }
    );

    println!("== encode/execute overlap: pipelined vs fused-sequential ==");
    let sequential = run(&[EngineKind::Native], 2000, 1, 64, 0)?;
    let pipelined = run(&[EngineKind::Native], 2000, 1, 64, 2)?;
    println!(
        "overlap speedup: {:.2}x (pipelined {pipelined:.0} q/s vs sequential {sequential:.0} q/s)",
        if sequential > 0.0 {
            pipelined / sequential
        } else {
            0.0
        }
    );
    Ok(())
}
