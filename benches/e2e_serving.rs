//! End-to-end serving benchmark: the full L3 staged pipeline (admission
//! -> batcher -> encoder -> executor -> responder) under different
//! engines, batch limits, worker counts and pipeline depths. This is the
//! measured companion to Fig. 11 / §5.4.3 on this machine, plus the
//! host-side overlap experiment: pipelined (encode of batch k+1
//! concurrent with execute of batch k) vs the fused sequential baseline.
//!
//!     cargo bench --bench e2e_serving

use spa_gcn::coordinator::server::{serve_workload, ServeConfig};
use spa_gcn::runtime::EngineKind;
use spa_gcn::util::bench::time_once;

/// Run one serve config and print the headline numbers plus the
/// per-stage latency split; returns the offered throughput (query/s).
fn run(
    engines: &[EngineKind],
    queries: usize,
    workers: usize,
    batch_max: usize,
    depth: usize,
) -> anyhow::Result<f64> {
    let label_engines = engines
        .iter()
        .map(EngineKind::as_str)
        .collect::<Vec<_>>()
        .join(",");
    let cfg = ServeConfig {
        engines: engines.to_vec(),
        queries,
        workers,
        batch_max,
        batch_timeout_us: 200,
        seed: 77,
        pipeline_depth: depth,
        ..ServeConfig::default()
    };
    let label = format!("serve {label_engines} q={queries} w={workers} b={batch_max} d={depth}");
    let (t, _) = time_once(&label, || serve_workload(&cfg).unwrap());
    let g = |k: &str| t.get(k).unwrap_or("-").to_string();
    println!(
        "    -> scored {}  throughput {} q/s  p50 {} ms  p99 {} ms  mean batch {}",
        g("queries scored"),
        g("throughput (query/s)"),
        g("latency p50 (ms)"),
        g("latency p99 (ms)"),
        g("mean batch size"),
    );
    println!(
        "       stage split: queue {} ms  encode {} ms  execute {} ms",
        g("queue wait mean (ms)"),
        g("encode mean (ms)"),
        g("execute mean (ms)"),
    );
    // MAC/element work rows are keyed per engine name (so a mixed
    // native,native-dense run keeps the two policies apart); cache rows
    // show what the embedding cache saved.
    for row in &t.rows {
        if row[0].ends_with(" macs mean")
            || row[0].ends_with(" ft elements mean")
            || row[0].ends_with(" agg elements mean")
            || row[0].starts_with("embed cache")
            || row[0] == "gcn forwards per query"
        {
            println!("       {}: {}", row[0], row[1]);
        }
    }
    println!();
    let tput = t
        .get("offered throughput (query/s)")
        .ok_or_else(|| anyhow::anyhow!("serve table missing offered-throughput row"))?;
    Ok(tput.parse()?)
}

/// One serve run returning (GCN forwards executed, wall seconds): the
/// one-vs-many accounting pair for the corpus sections below. `corpus`
/// of 0 means the classic pairwise workload; `workers` > 1 with a
/// corpus workload engages the scatter/gather path (top-k queries
/// split across the lanes, which share one embedding cache).
fn run_counted(
    queries: usize,
    corpus: usize,
    topk: usize,
    workers: usize,
) -> anyhow::Result<(f64, f64)> {
    let cfg = ServeConfig {
        engines: vec![EngineKind::Native],
        queries,
        workers,
        batch_max: 64,
        batch_timeout_us: 200,
        seed: 77,
        corpus_size: corpus,
        topk,
        ..ServeConfig::default()
    };
    let label = if corpus > 0 {
        format!("serve native corpus-search q={queries} corpus={corpus} topk={topk} w={workers}")
    } else {
        format!("serve native pairwise q={queries} w={workers}")
    };
    let (t, _) = time_once(&label, || serve_workload(&cfg).unwrap());
    let scored: f64 = t.get("queries scored").unwrap_or("0").parse()?;
    let forwards_per_query: f64 = t.get("gcn forwards per query").unwrap_or("0").parse()?;
    let wall: f64 = t.get("wall time (s)").unwrap_or("0").parse()?;
    let g = |k: &str| t.get(k).unwrap_or("-").to_string();
    println!(
        "    -> scored {scored}  gcn forwards/query {forwards_per_query}  \
         cache hit rate {}  wall {wall} s",
        g("embed cache hit rate"),
    );
    if corpus > 0 {
        println!(
            "       scatter: topk shards mean {}  lane spread {} ms  execute mean {} ms",
            g("topk shards mean"),
            g("topk lane spread (ms)"),
            g("execute mean (ms)"),
        );
    }
    Ok((scored * forwards_per_query, wall))
}

fn main() -> anyhow::Result<()> {
    println!("== engine comparison (measured on this machine) ==");
    for kind in [EngineKind::Native, EngineKind::Xla, EngineKind::XlaFused] {
        run(&[kind], 2000, 1, 64, 2)?;
    }

    println!("== batching sweep on the PJRT engine (real Fig. 11) ==");
    for b in [1usize, 4, 16, 64] {
        run(&[EngineKind::Xla], 1000, 1, b, 2)?;
    }

    println!("== worker scaling (native engine; 2-core machine) ==");
    for w in [1usize, 2] {
        run(&[EngineKind::Native], 2000, w, 64, 2)?;
    }

    println!("== heterogeneous lanes: native + sim in one pipeline ==");
    run(&[EngineKind::Native, EngineKind::Sim], 1000, 2, 64, 2)?;

    println!("== native scoring path: dense vs sparse (CSR + one-hot FT) ==");
    // Same numerics, two compute paths: the MAC/element rows quantify the
    // skipped work (Table 6's sparsity saving, measured in software) and
    // the throughput ratio is what that saving buys on this machine.
    let dense_qps = run(&[EngineKind::NativeDense], 2000, 1, 64, 2)?;
    let sparse_qps = run(&[EngineKind::Native], 2000, 1, 64, 2)?;
    println!(
        "sparse-path speedup: {:.2}x (sparse {sparse_qps:.0} q/s vs dense {dense_qps:.0} q/s)\n",
        if dense_qps > 0.0 {
            sparse_qps / dense_qps
        } else {
            0.0
        }
    );

    println!("== one-vs-many: pairwise fan-out vs cached corpus search (1 x 256) ==");
    // 256 candidate scorings asked two ways. Pairwise: 256 independent
    // pair queries over random db draws — the cache still dedups graphs
    // repeated across draws, so the measured count sits below the
    // cacheless 2-per-query bound (both are printed). Corpus search:
    // one TopK query against a 256-graph corpus — each unique graph
    // embeds once, then NTN+FCN fans out. The forward counts are the
    // Table-6-style work story; wall time is what the saving buys here.
    let (pair_fw, pair_wall) = run_counted(256, 0, 10, 1)?;
    let (corpus_fw, corpus_wall) = run_counted(1, 256, 10, 1)?;
    println!(
        "corpus-search saving: pairwise {:.0} GCN forwards measured (cacheless bound {}) vs \
         cached corpus {:.0} (cacheless bound {}), wall {:.4} s vs {:.4} s\n",
        pair_fw,
        2 * 256,
        corpus_fw,
        1 + 256,
        pair_wall,
        corpus_wall
    );

    println!("== scatter/gather: the 1 x 256 corpus query, single lane vs sharded ==");
    // The same one-vs-many query served whole on one lane, then
    // scattered across two corpus-capable lanes sharing one embedding
    // cache. The shard and lane-spread rows above show the split is
    // real and balanced; the forward counts must not grow with the
    // lane count (embed-once + shared cache), and the wall-time ratio
    // is what the Accel-GCN-style workload partitioning buys here.
    // run_serve waits for every lane's caps handshake before the
    // measured submit window, so the two-worker run scatters from the
    // very first query ("topk shards mean" prints 2, not a blend).
    let (single_fw, single_wall) = run_counted(64, 256, 10, 1)?;
    let (sharded_fw, sharded_wall) = run_counted(64, 256, 10, 2)?;
    println!(
        "scatter saving: single-lane {single_fw:.0} GCN forwards, wall {single_wall:.4} s vs \
         sharded {sharded_fw:.0} forwards, wall {sharded_wall:.4} s ({:.2}x)\n",
        if sharded_wall > 0.0 {
            single_wall / sharded_wall
        } else {
            0.0
        }
    );

    println!("== encode/execute overlap: pipelined vs fused-sequential ==");
    let sequential = run(&[EngineKind::Native], 2000, 1, 64, 0)?;
    let pipelined = run(&[EngineKind::Native], 2000, 1, 64, 2)?;
    println!(
        "overlap speedup: {:.2}x (pipelined {pipelined:.0} q/s vs sequential {sequential:.0} q/s)",
        if sequential > 0.0 {
            pipelined / sequential
        } else {
            0.0
        }
    );
    Ok(())
}
