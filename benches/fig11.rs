//! Bench: regenerate paper Fig. 11 (query batching) — simulated sweep plus
//! the real measured PJRT batching curve on this machine.
//!
//!     cargo bench --bench fig11
use spa_gcn::report::tables::{fig11, replication, Context};
use spa_gcn::util::bench::time_once;

fn main() -> anyhow::Result<()> {
    let ctx = Context::load(std::path::Path::new("artifacts"))?;
    let (t, _) = time_once("fig11 (256 queries, with PJRT)", || fig11(&ctx, 256, true));
    println!("\n{}", t.render());
    let (r, _) = time_once("replication (§5.4.3)", || replication(&ctx, 128));
    println!("\n{}", r.render());
    Ok(())
}
