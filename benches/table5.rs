//! Bench: regenerate paper Table 5 (SPA-GCN across KU15P/U50/U280).
//!
//!     cargo bench --bench table5
use spa_gcn::report::tables::{table5, Context};
use spa_gcn::util::bench::time_once;

fn main() -> anyhow::Result<()> {
    let ctx = Context::load(std::path::Path::new("artifacts"))?;
    let (t, _) = time_once("table5 (400 queries)", || table5(&ctx, 400));
    println!("\n{}", t.render());
    Ok(())
}
