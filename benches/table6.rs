//! Bench: regenerate paper Table 6 (FPGA-sim vs CPU vs GPU, plus the real
//! measured rust-native and PJRT engines on this machine).
//!
//!     cargo bench --bench table6
use spa_gcn::report::tables::{table6, Context};
use spa_gcn::util::bench::time_once;

fn main() -> anyhow::Result<()> {
    let ctx = Context::load(std::path::Path::new("artifacts"))?;
    let (t, _) = time_once("table6 (300 queries, with PJRT)", || table6(&ctx, 300, true));
    println!("\n{}", t.render());
    Ok(())
}
