//! Micro-benchmarks of the hot paths in every layer the rust side owns:
//! reference numerics (NativeEngine's inner loops), encoding, edge
//! reordering, the cycle simulator itself, and exact GED.
//!
//!     cargo bench --bench kernels

use spa_gcn::ged::exact_ged;
use spa_gcn::graph::encode::encode;
use spa_gcn::graph::generate::{generate, Family};
use spa_gcn::graph::normalize::normalized_edges;
use spa_gcn::graph::reorder::reorder_edges;
use spa_gcn::nn::linalg::matmul;
use spa_gcn::nn::simgnn::{gcn_forward, simgnn_forward};
use spa_gcn::report::tables::Context;
use spa_gcn::sim::config::ArchConfig;
use spa_gcn::sim::ft::{nonzero_stream, sparse_ft_cycles};
use spa_gcn::sim::gcn::simulate_query;
use spa_gcn::sim::platform::U280;
use spa_gcn::util::bench::bench;
use spa_gcn::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let ctx = Context::load(std::path::Path::new("artifacts"))?;
    let cfg = &ctx.cfg;
    let mut rng = Rng::new(0xbe9c);
    let g1 = generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels);
    let g2 = generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels);
    let e1 = encode(&g1, cfg.n_max, cfg.num_labels)?;
    let e2 = encode(&g2, cfg.n_max, cfg.num_labels)?;

    println!("-- L3 native numerics (NativeEngine hot path) --");
    let a: Vec<f32> = (0..32 * 64).map(|i| (i % 7) as f32 * 0.1).collect();
    let b: Vec<f32> = (0..64 * 32).map(|i| (i % 5) as f32 * 0.1).collect();
    bench("matmul 32x64x32 (dense)", || {
        std::hint::black_box(matmul(&a, &b, 32, 64, 32));
    });
    bench("gcn_forward (3 layers, one graph)", || {
        std::hint::black_box(gcn_forward(cfg, &ctx.weights, &e1));
    });
    bench("simgnn_forward (full pair)", || {
        std::hint::black_box(simgnn_forward(cfg, &ctx.weights, &e1, &e2));
    });

    println!("\n-- preprocessing (the paper's offline host steps) --");
    bench("encode (normalize A' + one-hot + pad)", || {
        std::hint::black_box(encode(&g1, cfg.n_max, cfg.num_labels).unwrap());
    });
    let edges = normalized_edges(&g1);
    bench("edge reorder (RAW window L=7)", || {
        std::hint::black_box(reorder_edges(&edges, 7));
    });

    println!("\n-- cycle simulator --");
    let trace = gcn_forward(cfg, &ctx.weights, &e1);
    let stream = nonzero_stream(&trace.layer_inputs[1], e1.num_nodes, cfg.filters[0]);
    let params = ArchConfig::spa_gcn().layers[1];
    bench("sparse FT arbiter sim (layer 2)", || {
        std::hint::black_box(sparse_ft_cycles(&stream, 32, &params, 7, 4));
    });
    let arch = ArchConfig::spa_gcn();
    let tr2 = gcn_forward(cfg, &ctx.weights, &e2);
    bench("simulate_query (full SimGNN pipeline)", || {
        std::hint::black_box(simulate_query(
            cfg,
            &arch,
            &U280,
            (&g1, &e1, &trace),
            (&g2, &e2, &tr2),
        ));
    });

    println!("\n-- exact GED (the NP-complete ground truth) --");
    let t1 = generate(&mut rng, Family::ErdosRenyi { n: 6, p_millis: 300 }, 32, 8);
    let t2g = generate(&mut rng, Family::ErdosRenyi { n: 6, p_millis: 300 }, 32, 8);
    bench("exact GED (6-node pair, A*)", || {
        std::hint::black_box(exact_ged(&t1, &t2g, 1_000_000));
    });
    Ok(())
}
