//! Micro-benchmarks of the hot paths in every layer the rust side owns:
//! reference numerics (NativeEngine's inner loops), encoding, edge
//! reordering, the cycle simulator itself, and exact GED — plus the
//! scalar-vs-vectorized kernel duel (DESIGN.md S16).
//!
//!     cargo bench --bench kernels
//!
//! The duel section re-times every dispatch-layer kernel on both paths
//! (csr_spmm across nnz regimes, sparse_row_matmul, onehot_gather, the
//! NTN+FCN tail, the full simgnn_forward) and overwrites `BENCH_6.json`
//! in the working directory with a machine-readable snapshot: p50 ns/op,
//! MACs/s and lanes-over-scalar speedup per kernel. That file is the
//! start of the repo's perf trajectory — re-run this bench after kernel
//! changes and commit the refreshed snapshot so CI history and future
//! re-anchors can see perf move, not just read changelogs.

use spa_gcn::ged::exact_ged;
use spa_gcn::graph::encode::encode;
use spa_gcn::graph::generate::{generate, Family};
use spa_gcn::graph::normalize::normalized_edges;
use spa_gcn::graph::reorder::reorder_edges;
use spa_gcn::nn::kernels::{self, KernelPath};
use spa_gcn::nn::linalg::matmul;
use spa_gcn::nn::simgnn::{attention_pool, gcn_forward, pair_score, simgnn_forward};
use spa_gcn::report::tables::Context;
use spa_gcn::sim::config::ArchConfig;
use spa_gcn::sim::ft::{nonzero_stream, sparse_ft_cycles};
use spa_gcn::sim::gcn::simulate_query;
use spa_gcn::sim::platform::U280;
use spa_gcn::util::bench::{bench, BenchResult};
use spa_gcn::util::json::{num, obj, s, Json};
use spa_gcn::util::rng::Rng;

/// One scalar-vs-lanes duel row for `BENCH_6.json`.
fn duel_row(
    kernel: &str,
    regime: &str,
    macs: u64,
    scalar: &BenchResult,
    lanes: &BenchResult,
) -> Json {
    let path = |r: &BenchResult| {
        obj(vec![
            ("p50_ns", num(r.p50_ns)),
            ("mean_ns", num(r.mean_ns)),
            ("macs_per_s", num(macs as f64 / (r.p50_ns * 1e-9))),
        ])
    };
    let speedup = scalar.p50_ns / lanes.p50_ns;
    println!(
        "   -> {kernel}/{regime}: {speedup:.2}x, lanes {:.2} GMAC/s",
        macs as f64 / lanes.p50_ns
    );
    obj(vec![
        ("kernel", s(kernel)),
        ("regime", s(regime)),
        ("macs_per_iter", num(macs as f64)),
        ("scalar", path(scalar)),
        ("lanes", path(lanes)),
        ("speedup_p50", num(speedup)),
    ])
}

fn main() -> anyhow::Result<()> {
    let ctx = Context::load(std::path::Path::new("artifacts"))?;
    let cfg = &ctx.cfg;
    let mut rng = Rng::new(0xbe9c);
    let g1 = generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels);
    let g2 = generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels);
    let e1 = encode(&g1, cfg.n_max, cfg.num_labels)?;
    let e2 = encode(&g2, cfg.n_max, cfg.num_labels)?;

    println!("-- L3 native numerics (NativeEngine hot path) --");
    let a: Vec<f32> = (0..32 * 64).map(|i| (i % 7) as f32 * 0.1).collect();
    let b: Vec<f32> = (0..64 * 32).map(|i| (i % 5) as f32 * 0.1).collect();
    bench("matmul 32x64x32 (dense)", || {
        std::hint::black_box(matmul(&a, &b, 32, 64, 32));
    });
    bench("gcn_forward (3 layers, one graph)", || {
        std::hint::black_box(gcn_forward(cfg, &ctx.weights, &e1));
    });
    bench("simgnn_forward (full pair)", || {
        std::hint::black_box(simgnn_forward(cfg, &ctx.weights, &e1, &e2));
    });

    println!("\n-- preprocessing (the paper's offline host steps) --");
    bench("encode (normalize A' + one-hot + pad)", || {
        std::hint::black_box(encode(&g1, cfg.n_max, cfg.num_labels).unwrap());
    });
    let edges = normalized_edges(&g1);
    bench("edge reorder (RAW window L=7)", || {
        std::hint::black_box(reorder_edges(&edges, 7));
    });

    println!("\n-- cycle simulator --");
    let trace = gcn_forward(cfg, &ctx.weights, &e1);
    let stream = nonzero_stream(&trace.layer_inputs[1], e1.num_nodes, cfg.filters[0]);
    let params = ArchConfig::spa_gcn().layers[1];
    bench("sparse FT arbiter sim (layer 2)", || {
        std::hint::black_box(sparse_ft_cycles(&stream, 32, &params, 7, 4));
    });
    let arch = ArchConfig::spa_gcn();
    let tr2 = gcn_forward(cfg, &ctx.weights, &e2);
    bench("simulate_query (full SimGNN pipeline)", || {
        std::hint::black_box(simulate_query(
            cfg,
            &arch,
            &U280,
            (&g1, &e1, &trace),
            (&g2, &e2, &tr2),
        ));
    });

    println!("\n-- exact GED (the NP-complete ground truth) --");
    let t1 = generate(&mut rng, Family::ErdosRenyi { n: 6, p_millis: 300 }, 32, 8);
    let t2g = generate(&mut rng, Family::ErdosRenyi { n: 6, p_millis: 300 }, 32, 8);
    bench("exact GED (6-node pair, A*)", || {
        std::hint::black_box(exact_ged(&t1, &t2g, 1_000_000));
    });

    // -- scalar vs vectorized kernel duel (DESIGN.md S16) -------------
    // Kernel-level duels call the scalar/lanes modules explicitly; the
    // nn-level tail and full-forward duels toggle the process-wide
    // dispatch (restored to the compiled default at the end).
    println!("\n-- scalar vs vectorized kernels (S16; writes BENCH_6.json) --");
    let mut rows_json: Vec<Json> = Vec::new();
    let f0 = cfg.filters[0];

    // csr_spmm across nnz regimes: sparse / AIDS-like / dense-ish
    // adjacency at full n_max, aggregating a layer-1-shaped X.
    for (regime, p_millis) in [("er-p100", 100), ("er-p350", 350), ("er-p800", 800)] {
        let g = generate(
            &mut rng,
            Family::ErdosRenyi { n: cfg.n_max, p_millis },
            cfg.n_max,
            cfg.num_labels,
        );
        let e = encode(&g, cfg.n_max, cfg.num_labels)?;
        let x: Vec<f32> = (0..cfg.n_max * f0).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
        let (_, macs) = kernels::scalar::csr_spmm(
            &e.csr.indptr, &e.csr.indices, &e.csr.weights, &x, cfg.n_max, f0,
        );
        let sc = bench(&format!("csr_spmm {regime} nnz={} [scalar]", e.csr.nnz()), || {
            std::hint::black_box(kernels::scalar::csr_spmm(
                &e.csr.indptr, &e.csr.indices, &e.csr.weights, &x, cfg.n_max, f0,
            ));
        });
        let ln = bench(&format!("csr_spmm {regime} nnz={} [lanes]", e.csr.nnz()), || {
            std::hint::black_box(kernels::lanes::csr_spmm(
                &e.csr.indptr, &e.csr.indices, &e.csr.weights, &x, cfg.n_max, f0,
            ));
        });
        rows_json.push(duel_row("csr_spmm", regime, macs, &sc, &ln));
    }

    // sparse_row_matmul on a real post-ReLU layer-1 input.
    let h1 = &trace.layer_inputs[1];
    let (f_in, f_out) = (cfg.filters[0], cfg.filters[1]);
    let (_, _, srm_macs) = kernels::scalar::sparse_row_matmul(
        h1, &ctx.weights.gcn_w[1], e1.num_nodes, cfg.n_max, f_in, f_out,
    );
    let sc = bench("sparse_row_matmul layer1 [scalar]", || {
        std::hint::black_box(kernels::scalar::sparse_row_matmul(
            h1, &ctx.weights.gcn_w[1], e1.num_nodes, cfg.n_max, f_in, f_out,
        ));
    });
    let ln = bench("sparse_row_matmul layer1 [lanes]", || {
        std::hint::black_box(kernels::lanes::sparse_row_matmul(
            h1, &ctx.weights.gcn_w[1], e1.num_nodes, cfg.n_max, f_in, f_out,
        ));
    });
    rows_json.push(duel_row("sparse_row_matmul", "post-relu-layer1", srm_macs, &sc, &ln));

    // onehot_gather on the layer-0 one-hot features.
    let (_, _, og_macs) = kernels::scalar::onehot_gather(
        &e1.h0, &ctx.weights.gcn_w[0], e1.num_nodes, cfg.n_max, cfg.num_labels, f0,
    );
    let sc = bench("onehot_gather layer0 [scalar]", || {
        std::hint::black_box(kernels::scalar::onehot_gather(
            &e1.h0, &ctx.weights.gcn_w[0], e1.num_nodes, cfg.n_max, cfg.num_labels, f0,
        ));
    });
    let ln = bench("onehot_gather layer0 [lanes]", || {
        std::hint::black_box(kernels::lanes::onehot_gather(
            &e1.h0, &ctx.weights.gcn_w[0], e1.num_nodes, cfg.n_max, cfg.num_labels, f0,
        ));
    });
    rows_json.push(duel_row("onehot_gather", "aids-onehot", og_macs, &sc, &ln));

    // NTN + FCN tail on real graph embeddings (dispatch toggled).
    let hg1 = attention_pool(cfg, &ctx.weights, &trace.embeddings, &e1.mask);
    let hg2 = attention_pool(cfg, &ctx.weights, &tr2.embeddings, &e2.mask);
    let f = cfg.embed_dim();
    let tail_macs = {
        let ntn = cfg.ntn_k as u64 * (f as u64 * f as u64 + 2 * f as u64);
        let mut d = cfg.ntn_k as u64;
        let mut fcn = 0u64;
        for &h in &cfg.fc_dims {
            fcn += d * h as u64;
            d = h as u64;
        }
        ntn + fcn + d
    };
    kernels::set_kernel_path(KernelPath::Scalar);
    let sc = bench("ntn+fcn tail (pair_score) [scalar]", || {
        std::hint::black_box(pair_score(cfg, &ctx.weights, &hg1, &hg2));
    });
    kernels::set_kernel_path(KernelPath::Lanes);
    let ln = bench("ntn+fcn tail (pair_score) [lanes]", || {
        std::hint::black_box(pair_score(cfg, &ctx.weights, &hg1, &hg2));
    });
    rows_json.push(duel_row("ntn_fcn_tail", "pair-tail", tail_macs, &sc, &ln));

    // Full pair forward (GCN + attention + tail) under each path.
    let fwd_macs = trace.macs + tr2.macs + tail_macs;
    kernels::set_kernel_path(KernelPath::Scalar);
    let sc = bench("simgnn_forward full pair [scalar]", || {
        std::hint::black_box(simgnn_forward(cfg, &ctx.weights, &e1, &e2));
    });
    kernels::set_kernel_path(KernelPath::Lanes);
    let ln = bench("simgnn_forward full pair [lanes]", || {
        std::hint::black_box(simgnn_forward(cfg, &ctx.weights, &e1, &e2));
    });
    rows_json.push(duel_row("simgnn_forward", "full-pair", fwd_macs, &sc, &ln));
    kernels::set_kernel_path(KernelPath::compiled_default());

    let doc = obj(vec![
        ("bench", s("kernels")),
        ("schema", s("bench-kernels-v1")),
        ("pr", num(6.0)),
        ("provenance", s("measured")),
        ("lane_width", num(kernels::LANE_WIDTH as f64)),
        ("compiled_default", s(KernelPath::compiled_default().as_str())),
        ("model", obj(vec![
            ("n_max", num(cfg.n_max as f64)),
            ("num_labels", num(cfg.num_labels as f64)),
            ("embed_dim", num(cfg.embed_dim() as f64)),
            ("ntn_k", num(cfg.ntn_k as f64)),
        ])),
        ("kernels", Json::Arr(rows_json)),
    ]);
    std::fs::write("BENCH_6.json", doc.to_string() + "\n")?;
    println!("wrote BENCH_6.json");
    Ok(())
}
