//! Ablation benches beyond the paper's headline tables: accuracy of
//! SimGNN vs classical GED heuristics, energy per query, FIFO-depth
//! backpressure, and the edge-reordering preprocessing.
//!
//!     cargo bench --bench ablations
use spa_gcn::graph::generate::{generate, Family};
use spa_gcn::graph::normalize::normalized_edges;
use spa_gcn::graph::reorder::{raw_stall_cycles, reorder_edges};
use spa_gcn::report::tables::{accuracy, energy, fifo_ablation, sparsity, Context};
use spa_gcn::util::bench::time_once;
use spa_gcn::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let ctx = Context::load(std::path::Path::new("artifacts"))?;

    let (t, _) = time_once("accuracy (48 exact-GED pairs)", || accuracy(&ctx, 48));
    println!("\n{}", t.render());

    let (t, _) = time_once("energy (128 queries)", || energy(&ctx, 128));
    println!("\n{}", t.render());

    let (t, _) = time_once("fifo ablation (24 queries)", || fifo_ablation(&ctx, 24));
    println!("\n{}", t.render());

    let (t, _) = time_once("sparsity (64 queries)", || sparsity(&ctx, 64));
    println!("\n{}", t.render());

    // Edge-reordering ablation: aggregate RAW stalls with and without the
    // paper's offline preprocessing (§3.2.2) over a workload.
    let mut rng = Rng::new(0xab1a);
    let mut stalls_sorted = 0usize;
    let mut stalls_reordered = 0usize;
    let mut edges_total = 0usize;
    for _ in 0..200 {
        let g = generate(&mut rng, Family::Aids, 32, 29);
        let edges = normalized_edges(&g);
        edges_total += edges.len();
        stalls_sorted += raw_stall_cycles(&edges, 7);
        stalls_reordered += raw_stall_cycles(&reorder_edges(&edges, 7).edges, 7);
    }
    println!("\n== edge-reorder ablation (200 AIDS-like graphs, L=7) ==");
    println!("edges streamed             {edges_total}");
    println!(
        "RAW stalls (dst-sorted)    {stalls_sorted} ({:.1}% overhead)",
        100.0 * stalls_sorted as f64 / edges_total as f64
    );
    println!("RAW stalls (reordered)     {stalls_reordered} (paper: II=1, zero stalls)");
    Ok(())
}
